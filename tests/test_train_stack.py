"""Train stack: optimizer, checkpointing (atomic/async/elastic), trainer
fault tolerance, data pipeline, gradient compression."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import Prefetcher, SyntheticLM, TokenFileDataset
from repro.parallel import compression
from repro.train import optimizer as optim
from repro.train import trainer as tr


def test_adamw_decreases_quadratic():
    cfg = optim.AdamWConfig(lr_peak=0.1, warmup_steps=5, total_steps=100,
                            weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = optim.adamw_init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, m = optim.adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_lr_schedule_shape():
    cfg = optim.AdamWConfig(lr_peak=1e-3, lr_min=1e-5, warmup_steps=10,
                            total_steps=100)
    lrs = [float(optim.lr_at(cfg, s)) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-5, rel=1e-2)


def test_grad_clipping_applied():
    cfg = optim.AdamWConfig(clip_norm=1.0, lr_peak=1.0, warmup_steps=0,
                            total_steps=1, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = optim.adamw_init(params)
    _, _, m = optim.adamw_update(cfg, {"w": jnp.full(4, 100.0)}, state,
                                 params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": np.arange(6).reshape(2, 3).astype(np.float32),
            "b": [np.ones(4), np.zeros((2, 2))]}
    ckpt.save(str(tmp_path), tree, step=7, meta={"x": 1})
    out, step, meta = ckpt.restore(str(tmp_path), tree)
    assert step == 7 and meta == {"x": 1}
    np.testing.assert_array_equal(np.asarray(out["a"]), tree["a"])
    # LATEST points at a complete checkpoint even with a stale tmp dir
    os.makedirs(str(tmp_path / "step_00000009.tmp"), exist_ok=True)
    assert ckpt.latest_step(str(tmp_path)) == 7


def test_async_checkpointer(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path))
    c.save_async({"w": jnp.ones(8)}, step=1)
    c.wait()
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_prune(tmp_path):
    for s in [1, 2, 3, 4]:
        ckpt.save(str(tmp_path), {"w": np.zeros(2)}, step=s)
    ckpt.prune_old(str(tmp_path), keep=2)
    steps = sorted(int(d[5:]) for d in os.listdir(str(tmp_path))
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_trainer_failure_recovery(tmp_path):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = configs.get_smoke_config("phi3-mini-3.8b", n_layers=2,
                                   d_model=64, vocab=128)
    tc = tr.TrainerConfig(total_steps=40, ckpt_every=10,
                          ckpt_dir=str(tmp_path), log_every=100)
    oc = optim.AdamWConfig(lr_peak=5e-3, warmup_steps=5, total_steps=40)
    data = SyntheticLM(vocab=128, batch=4, seq_len=32)
    t = tr.Trainer(tc, cfg, oc, mesh, data)
    t.inject_failure_at = 25
    out = t.fit()
    assert out["restarts"] == 1
    assert out["step"] == 40
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0]


def test_trainer_resume_from_checkpoint(tmp_path):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = configs.get_smoke_config("phi3-mini-3.8b", n_layers=2,
                                   d_model=64, vocab=128)
    oc = optim.AdamWConfig(lr_peak=5e-3, warmup_steps=5, total_steps=30)
    tc1 = tr.TrainerConfig(total_steps=20, ckpt_every=10,
                           ckpt_dir=str(tmp_path), log_every=100)
    tr.Trainer(tc1, cfg, oc, mesh,
               SyntheticLM(vocab=128, batch=4, seq_len=32)).fit()
    tc2 = tr.TrainerConfig(total_steps=30, ckpt_every=10,
                           ckpt_dir=str(tmp_path), log_every=100)
    out = tr.Trainer(tc2, cfg, oc, mesh,
                     SyntheticLM(vocab=128, batch=4, seq_len=32)).fit(
        resume=True)
    assert out["step"] == 30
    # resumed run performed only 10 new steps
    assert len(out["metrics"]) == 10


def test_das_gate_fast_slow():
    calls = []
    g = tr.DASGate(rate_thr=0.5, inflation_thr=2.0,
                   replan=lambda: calls.append(1))
    assert g.decide(0.1, 3.0) == "fast"
    assert g.decide(0.9, 1.0) == "fast"
    assert g.decide(0.9, 3.0) == "slow"
    assert len(calls) == 1


def test_synthetic_data_learnable_and_deterministic():
    d1 = SyntheticLM(vocab=64, batch=2, seq_len=16, seed=3)
    d2 = SyntheticLM(vocab=64, batch=2, seq_len=16, seed=3)
    b1, b2 = next(d1), next(d2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_token_file_dataset(tmp_path):
    toks = np.arange(1000, dtype=np.int32)
    p = tmp_path / "shard0.bin"
    toks.tofile(str(p))
    ds = TokenFileDataset([str(p)], batch=2, seq_len=9)
    b = next(ds)
    assert b["tokens"].shape == (2, 9)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetcher_delivers_in_order():
    src = iter([{"x": np.array([i])} for i in range(5)])
    pf = Prefetcher(src, depth=2)
    got = [int(b["x"][0]) for b in pf]
    assert got == list(range(5))


def test_int8_compression_accuracy():
    g = {"w": jnp.linspace(-3, 3, 1000)}
    gq = compression.fake_requantize(g)
    err = float(jnp.max(jnp.abs(gq["w"] - g["w"])))
    assert err <= 3 / 127.0 + 1e-6


def test_compressed_psum_shard_map():
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.arange(8, dtype=jnp.float32)

    f = shard_map(lambda v: compression.compressed_psum(v, "data"),
                  mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    out = f(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=0.05)

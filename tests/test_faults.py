"""Fault injection, retry and graceful degradation (PR-7 tentpole).

Invariants under a `faults.FaultPlan`:
  * the healthy plan is the identity — bit-identical results to running
    without a plan, for every scheduler mode;
  * a fully-dead accelerator cluster degrades its task types to the CPU
    clusters and every job still completes;
  * retry exhaustion and per-job deadlines drop jobs instead of stalling,
    with consistent accounting;
  * the batched (vmapped) path is bit-exact with per-scenario `sim.run`
    when plans ride the scenario axis;
  * no completed task ever occupies a PE inside its dead window
    (hypothesis property, skips without the package);
  * the independent float64 reference simulator agrees under faults.
"""
import numpy as np
import pytest

from hyp_compat import hypothesis, st
from repro.core import faults, ref_sim, simulator as sim, soc, workloads

PARAMS = sim.make_params()
SUITE = workloads.default_suite(n_instances=8)
WL = SUITE.build(5, 6)

ALL_MODES = [sim.MODE_LUT, sim.MODE_ETF, sim.MODE_ETF_IDEAL, sim.MODE_DAS,
             sim.MODE_ORACLE, sim.MODE_THRESHOLD]
# fields that exist without fault injection (must be plan-invariant)
BASE_FIELDS = sim.SimResult._fields[:21]
FAULT_COUNTERS = ("n_faults", "n_retries", "reexec_us", "n_dropped_jobs",
                  "n_dropped_tasks", "recovery_us", "n_recovered")

FFT_PES = np.where(soc.PE_CLUSTER == soc.FFT_ACC)[0]
FFT_TYPES = [i for i, n in enumerate(soc.TASK_TYPE_NAMES)
             if n in ("fft", "ifft")]


def _tree():
    import jax.numpy as jnp
    return sim.DTree(feat=jnp.array([sim.FEAT_RATE, 1, 1], jnp.int32),
                     thr=jnp.array([500.0, 4.0, 6.0], jnp.float32),
                     leaf=jnp.array([0, 1, 0, 1], jnp.int32))


def _assert_results_equal(a, b, fields=sim.SimResult._fields):
    for name in fields:
        va, vb = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        assert np.array_equal(va, vb, equal_nan=True), name


# ---------------------------------------------------------------------------
# healthy plan == no plan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ALL_MODES)
def test_healthy_plan_is_identity(mode):
    kw = {"tree": _tree()} if mode == sim.MODE_DAS else {}
    if mode == sim.MODE_THRESHOLD:
        kw["rate_threshold"] = 600.0
    r0 = sim.run(mode, WL, PARAMS, **kw)
    r1 = sim.run(mode, WL, PARAMS, plan=faults.healthy_plan(), **kw)
    _assert_results_equal(r0, r1, BASE_FIELDS)
    for name in FAULT_COUNTERS:
        assert float(np.asarray(getattr(r1, name))) == 0.0, name
    assert not np.asarray(r1.job_dropped).any()


# ---------------------------------------------------------------------------
# graceful degradation: dead accelerator cluster -> CPU fallback
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", [sim.MODE_LUT, sim.MODE_ETF, sim.MODE_DAS])
def test_dead_fft_cluster_degrades_to_cpu(mode):
    plan = faults.fail_cluster(faults.healthy_plan(), soc.FFT_ACC, at=0.0)
    kw = {"tree": _tree()} if mode == sim.MODE_DAS else {}
    r = sim.run(mode, WL, PARAMS, plan=plan, **kw)
    healthy = sim.run(mode, WL, PARAMS, **kw)
    assert int(r.n_done) == int(WL.n_tasks)
    assert not bool(r.stalled)
    assert int(r.n_dropped_jobs) == 0
    pe_of = np.asarray(r.pe_of)[: int(WL.n_tasks)]
    assert not np.isin(pe_of, FFT_PES).any(), "task placed on a dead PE"
    tt = np.asarray(WL.task_type)[: int(WL.n_tasks)]
    fft_tasks = np.isin(tt, FFT_TYPES)
    assert fft_tasks.any()
    # fft work fell back to the CPU clusters => strictly slower on average
    assert float(r.avg_exec_us) > float(healthy.avg_exec_us)


def test_cluster_slowdown_stretches_exec():
    plan = faults.slow_cluster(faults.healthy_plan(), soc.LITTLE, 3.0)
    r = sim.run(sim.MODE_LUT, WL, PARAMS, plan=plan)
    healthy = sim.run(sim.MODE_LUT, WL, PARAMS)
    assert int(r.n_done) == int(WL.n_tasks)
    assert float(r.avg_exec_us) > float(healthy.avg_exec_us)


# ---------------------------------------------------------------------------
# retries, exhaustion, deadlines
# ---------------------------------------------------------------------------
def _transient_storm(times, pes=None, retries=0):
    plan = faults.with_retries(faults.healthy_plan(), retries)
    for pe in (range(soc.N_PES) if pes is None else pes):
        for t in times:
            plan = faults.add_transient(plan, int(pe), float(t))
    return plan


def test_transient_kills_and_recovers():
    plan = _transient_storm([1.0, 3.0], retries=4)
    r = sim.run(sim.MODE_ETF, WL, PARAMS, plan=plan)
    assert int(r.n_done) == int(WL.n_tasks)
    assert not bool(r.stalled)
    assert int(r.n_faults) > 0
    assert int(r.n_retries) == int(r.n_faults)  # budget never exhausted
    assert int(r.n_dropped_jobs) == 0
    assert int(r.n_recovered) > 0
    assert float(r.recovery_us) > 0
    assert float(r.reexec_us) >= 0


def test_retry_exhaustion_drops_jobs_and_terminates():
    plan = _transient_storm([1.0, 3.0], retries=0)
    r = sim.run(sim.MODE_ETF, WL, PARAMS, plan=plan)
    # every kill immediately exhausts the zero budget -> job drops
    assert int(r.n_faults) > 0
    assert int(r.n_retries) == 0
    assert int(r.n_dropped_jobs) > 0
    assert int(r.n_dropped_tasks) >= int(r.n_dropped_jobs)
    assert not bool(r.stalled)
    # dropped tasks count toward termination: the loop converges
    assert int(r.n_done) == int(WL.n_tasks)
    assert int(np.asarray(r.job_dropped).sum()) == int(r.n_dropped_jobs)


def test_deadline_drops_late_jobs():
    plan = faults.with_deadline(faults.healthy_plan(), 2.0)
    r = sim.run(sim.MODE_LUT, WL, PARAMS, plan=plan)
    assert int(r.n_dropped_jobs) > 0
    assert not bool(r.stalled)
    assert int(r.n_done) == int(WL.n_tasks)
    # dropped instances are excluded from the latency average
    inst = np.asarray(r.inst_exec_us)[: int(WL.n_insts)]
    dropped = np.asarray(r.job_dropped)[: int(WL.n_insts)]
    assert np.isnan(inst[dropped]).all()
    kept = inst[~dropped]
    if kept.size:
        assert np.isfinite(kept).all()
        assert (kept <= 2.0 + 1e-3).all()


# ---------------------------------------------------------------------------
# batched path bit-exactness under plans
# ---------------------------------------------------------------------------
PLANS = [
    faults.healthy_plan(),
    faults.fail_cluster(faults.healthy_plan(), soc.FFT_ACC, 0.0),
    faults.fail_pes(faults.with_retries(faults.healthy_plan(), 2),
                    [0, 8, 12], 2.0, repair_at=6.0),
    faults.with_deadline(
        faults.slow_cluster(faults.healthy_plan(), soc.BIG, 2.0), 40.0),
]


@pytest.mark.parametrize("mode", [sim.MODE_LUT, sim.MODE_ETF, sim.MODE_DAS])
def test_batched_matches_sequential_with_stacked_plans(mode):
    cells = [(0, 3), (5, 6), (5, 13), (1, 9)]
    wls = [SUITE.build(mi, ri) for mi, ri in cells]
    kw = {"tree": _tree()} if mode == sim.MODE_DAS else {}
    batched = sim.run_batch(mode, workloads.stack_workloads(wls), PARAMS,
                            plan=faults.stack_plans(PLANS), **kw)
    for k, (wl, plan) in enumerate(zip(wls, PLANS)):
        seq = sim.run(mode, wl, PARAMS, plan=plan, **kw)
        _assert_results_equal(sim.result_at(batched, k), seq)


def test_batched_shared_plan_and_chunking():
    plan = PLANS[2]
    wls = [SUITE.build(5, ri) for ri in (0, 4, 8, 13)]
    stacked = workloads.stack_workloads(wls)
    full = sim.run_batch(sim.MODE_ETF, stacked, PARAMS, plan=plan)
    chunked = sim.run_batch(sim.MODE_ETF, stacked, PARAMS, plan=plan,
                            batch_size=2)
    _assert_results_equal(full, chunked)
    for k, wl in enumerate(wls):
        seq = sim.run(sim.MODE_ETF, wl, PARAMS, plan=plan)
        _assert_results_equal(sim.result_at(full, k), seq)


# ---------------------------------------------------------------------------
# property: the availability mask is always respected
# ---------------------------------------------------------------------------
@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(st.integers(min_value=0, max_value=10_000))
def test_completed_tasks_never_occupy_dead_pes(seed):
    """No completed task's final run [start, finish) may overlap its PE's
    dead window [fail_at, repair_at)."""
    plan = faults.random_plan(seed, n_fail=3, n_transient=4,
                              t_horizon_us=60.0, max_retries=3)
    r = sim.run(sim.MODE_ETF, WL, PARAMS, plan=plan)
    assert not bool(r.stalled)
    nt = int(WL.n_tasks)
    done = np.asarray(r.finish)[:nt] > -np.inf
    done &= ~np.asarray(r.job_dropped)[np.asarray(WL.inst_id)[:nt]]
    pe_of = np.asarray(r.pe_of)[:nt]
    tt = np.asarray(WL.task_type)[:nt]
    exec_pe = np.asarray(PARAMS.exec_pe)  # slowdown is 1.0 in random_plan
    finish = np.asarray(r.finish)[:nt]
    start = finish - exec_pe[tt, np.clip(pe_of, 0, None)]
    fail = np.asarray(plan.pe_fail_at)[np.clip(pe_of, 0, None)]
    repair = np.asarray(plan.pe_repair_at)[np.clip(pe_of, 0, None)]
    overlap = done & (start < repair) & (fail < finish - 1e-6)
    assert not overlap.any(), np.where(overlap)[0][:5]


# ---------------------------------------------------------------------------
# reference-simulator differential under faults
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", [sim.MODE_LUT, sim.MODE_ETF])
@pytest.mark.parametrize("plan_idx", [1, 2])
def test_reference_sim_agrees_under_faults(mode, plan_idx):
    plan = PLANS[plan_idx]
    r_jax = sim.run(mode, WL, PARAMS, plan=plan)
    r_ref = ref_sim.simulate_ref(mode, WL, plan=plan)
    assert int(r_jax.n_done) == r_ref["n_done"]
    for name in ("n_faults", "n_retries", "n_dropped_jobs",
                 "n_dropped_tasks", "n_recovered"):
        assert int(np.asarray(getattr(r_jax, name))) == r_ref[name], name
    nt = int(WL.n_tasks)
    fin_jax = np.asarray(r_jax.finish)[:nt]
    fin_ref = r_ref["finish"][:nt]
    ok = np.isfinite(fin_jax) & np.isfinite(fin_ref)
    diff = np.abs(fin_jax[ok] - fin_ref[ok])
    assert (diff <= 1e-3 * max(1.0, float(np.abs(fin_ref[ok]).max()))
            ).mean() >= 0.98
    assert float(r_jax.avg_exec_us) == pytest.approx(
        r_ref["avg_exec_us"], rel=1e-3, abs=1e-3, nan_ok=True)


# ---------------------------------------------------------------------------
# validation errors
# ---------------------------------------------------------------------------
def test_validate_plan_rejects_malformed():
    with pytest.raises(ValueError, match="repair"):
        faults.validate_plan(faults.fail_pes(
            faults.healthy_plan(), [0], at=5.0, repair_at=1.0))
    with pytest.raises(ValueError, match="slowdown"):
        faults.validate_plan(faults.slow_cluster(
            faults.healthy_plan(), soc.BIG, 0.5))
    with pytest.raises(ValueError, match="max_retries"):
        faults.validate_plan(faults.with_retries(faults.healthy_plan(), -1))
    with pytest.raises(ValueError, match="trailing dim"):
        faults.validate_plan(faults.healthy_plan(n_pes=7))
    # run() rejects a batched plan; run_batch rejects a mis-sized one
    stacked = faults.stack_plans([faults.healthy_plan()] * 2)
    with pytest.raises(ValueError):
        sim.run(sim.MODE_LUT, WL, PARAMS, plan=stacked)
    with pytest.raises(ValueError):
        sim.run_batch(sim.MODE_LUT,
                      workloads.stack_workloads([WL, WL, WL]),
                      PARAMS, plan=stacked)


def test_validate_workload_rejects_malformed():
    wl = SUITE.build(0, 0)
    tt = np.array(wl.task_type)
    tt[2] = soc.N_TASK_TYPES
    with pytest.raises(ValueError, match="task_type"):
        workloads.validate_workload(wl._replace(task_type=tt))
    kb = np.array(wl.out_kb)
    kb[1] = -1.0
    with pytest.raises(ValueError, match="out_kb"):
        workloads.validate_workload(wl._replace(out_kb=kb))
    pr, npred = np.array(wl.preds), np.array(wl.n_preds)
    pr[1, 0], npred[1] = 1, 1  # self-dependency = 1-cycle
    with pytest.raises(ValueError, match="cycle"):
        workloads.validate_workload(wl._replace(preds=pr, n_preds=npred))


def test_validate_config_rejects_malformed():
    import dataclasses
    cfg = soc.default_soc()
    bad_lut = np.array(cfg.lut_cluster)
    bad_lut[0] = soc.FFT_ACC  # scrambler cannot run on the FFT accelerator
    with pytest.raises(ValueError, match="lut_cluster"):
        soc.validate_config(dataclasses.replace(cfg, lut_cluster=bad_lut))
    bad_power = np.array(cfg.cluster_power)
    bad_power[0] = -1.0
    with pytest.raises(ValueError, match="cluster_power"):
        soc.validate_config(dataclasses.replace(cfg, cluster_power=bad_power))


# ---------------------------------------------------------------------------
# plan-builder edge cases (hypothesis properties; skip without the package)
# ---------------------------------------------------------------------------
def test_stack_plans_rejects_zero_length():
    with pytest.raises(ValueError, match="at least one"):
        faults.stack_plans([])


@hypothesis.settings(max_examples=8, deadline=None)
@hypothesis.given(st.integers(min_value=0, max_value=10_000),
                  st.integers(min_value=1, max_value=4))
def test_stack_plans_slices_back_bit_exact(seed, n):
    """Stacking then indexing scenario k recovers plan k exactly, and the
    stacked plan still validates (leading axes are allowed)."""
    plans = [faults.random_plan(seed + k) for k in range(n)]
    stacked = faults.stack_plans(plans)
    assert faults.is_batched(stacked)
    faults.validate_plan(stacked)
    for k, p in enumerate(plans):
        for name, field in zip(faults.FaultPlan._fields, stacked):
            np.testing.assert_array_equal(
                np.asarray(field)[k], np.asarray(getattr(p, name)),
                err_msg=f"{name}[{k}]")


@hypothesis.settings(max_examples=6, deadline=None)
@hypothesis.given(st.floats(min_value=0.0, max_value=50.0,
                            allow_nan=False))
def test_all_pes_dead_finite_deadline_never_stalls(at):
    """Every PE permanently dead at `at` with a finite job deadline: the
    simulator must terminate by dropping, never by deadlocking."""
    plan = faults.with_deadline(
        faults.fail_pes(faults.healthy_plan(), range(soc.N_PES), at=at),
        2000.0)
    r = sim.run(sim.MODE_ETF, WL, PARAMS, plan=plan)
    assert not bool(r.stalled)
    assert int(r.stall_reason) == sim.STALL_NONE
    n_jobs = int(np.asarray(WL.inst_id).max()) + 1
    assert int(np.asarray(r.job_dropped).sum()) == int(r.n_dropped_jobs)
    if at == 0.0:
        assert int(r.n_dropped_jobs) == n_jobs  # nothing could ever run


def test_all_pes_dead_infinite_deadline_is_a_deadlock_stall():
    """The same scenario without a deadline cannot make progress and must
    be *reported* as a deadlock stall, not spin forever."""
    plan = faults.fail_pes(faults.healthy_plan(), range(soc.N_PES), at=0.0)
    r = sim.run(sim.MODE_ETF, WL, PARAMS, plan=plan)
    assert bool(r.stalled)
    assert int(r.stall_reason) == sim.STALL_DEADLOCK
    assert int(r.n_done) == 0


@hypothesis.settings(max_examples=8, deadline=None)
@hypothesis.given(st.integers(min_value=0, max_value=10_000))
def test_retry_budget_zero_never_retries(seed):
    """max_retries=0: a fault's kill is final — no re-enqueues, and each
    fault can take down at most the one job it interrupted."""
    plan = faults.random_plan(seed, n_fail=3, n_transient=4,
                              t_horizon_us=20.0, max_retries=0)
    r = sim.run(sim.MODE_ETF, WL, PARAMS, plan=plan)
    assert not bool(r.stalled)
    assert int(r.n_retries) == 0
    assert int(r.n_dropped_jobs) <= int(r.n_faults)


# ---------------------------------------------------------------------------
# static capability gating: plans that can never kill / drop skip those
# phases at trace time, bit-exactly
# ---------------------------------------------------------------------------
def test_plan_capabilities_flags():
    hp = faults.healthy_plan()
    assert faults.plan_capabilities(hp) == (False, False, False)
    p0 = faults.fail_pes(hp, [0, 1], at=0.0)
    # fail at t=0 can kill nothing (assignments need assign_t < tau)
    assert faults.plan_capabilities(p0) == (True, False, False)
    pt = faults.fail_pes(hp, [0], at=25.0)
    assert faults.plan_capabilities(pt) == (True, True, False)
    pd = faults.with_deadline(hp, 1e4)
    assert faults.plan_capabilities(pd) == (False, False, True)
    tr = faults.add_transient(hp, 3, at=40.0)
    assert faults.plan_capabilities(tr) == (False, True, False)


@pytest.mark.parametrize("mode", [sim.MODE_LUT, sim.MODE_ETF, sim.MODE_DAS])
def test_gated_kill_phase_bit_exact_vs_full_machinery(mode):
    """A fail-at-t=0 plan traces without the kill/drop machinery
    (`can_kill=False`). Adding one finite transient far past the makespan
    forces the FULL machinery back in while firing nothing — both
    specializations must agree bit-for-bit, sequential and batched."""
    kw = {"tree": _tree()} if mode == sim.MODE_DAS else {}
    base = faults.fail_cluster(faults.healthy_plan(), soc.FFT_ACC, at=0.0)
    armed = faults.add_transient(base, 0, at=1e30)   # finite, never fires
    assert faults.plan_capabilities(base) == (True, False, False)
    assert faults.plan_capabilities(armed) == (True, True, False)
    r_gated = sim.run(mode, WL, PARAMS, plan=base, **kw)
    r_full = sim.run(mode, WL, PARAMS, plan=armed, **kw)
    _assert_results_equal(r_gated, r_full)

    wl_b = workloads.stack_workloads([WL] * 3)
    rb_g = sim.run_batch(mode, wl_b, PARAMS,
                         plan=faults.stack_plans([base] * 3),
                         batch_size=2, **kw)
    rb_f = sim.run_batch(mode, wl_b, PARAMS,
                         plan=faults.stack_plans([armed] * 3),
                         batch_size=2, **kw)
    _assert_results_equal(rb_g, rb_f)
    _assert_results_equal(r_gated, sim.result_at(rb_g, 1))


def test_gated_deadline_phase_bit_exact_when_slack():
    """A deadline far beyond the makespan (finite -> full machinery) vs no
    deadline (gated) on an otherwise identical degraded plan: nothing
    drops, results identical."""
    base = faults.fail_cluster(faults.healthy_plan(), soc.FFT_ACC, at=0.0)
    slack = faults.with_deadline(base, 1e30)
    assert faults.plan_capabilities(slack)[2]
    r_gated = sim.run(sim.MODE_ETF, WL, PARAMS, plan=base)
    r_full = sim.run(sim.MODE_ETF, WL, PARAMS, plan=slack)
    assert int(r_full.n_dropped_jobs) == 0
    _assert_results_equal(r_gated, r_full)

"""Crash-safe sweep campaigns (PR-9 tentpole, `repro.core.campaign`).

The acceptance bar:

  * a campaign killed after k of n chunks, resumed against the same
    checkpoint dir, is **bit-exact** vs one uninterrupted `run_batch`
    sweep — for all six scheduler modes, and under stacked FaultPlans;
  * injected chunk failures (forced OOM, watchdog trips, step-budget
    stalls) are retried with backoff, the final grid is complete, and the
    retry/shrink counters are visible in the stats that feed
    `benchmarks.run --json`;
  * checkpoints are reused (not recomputed) on resume, corrupt chunk
    files are deleted and recomputed, and the autotune probe cache in
    `benchmarks.common` survives corruption the same way.

"Kill" here is a non-retryable exception injected into the chunk compute
after k dispatches — the same observable state as a SIGKILL (k completed
chunk files + a manifest); the real-SIGKILL variant runs in CI via
`benchmarks.kill_resume_smoke`.
"""
import json
import os
import time
import types

import numpy as np
import pytest

from repro.core import campaign as camp, faults as flt, simulator as sim, \
    workloads

PARAMS = sim.make_params()
SUITE = workloads.default_suite(n_instances=4)
# 5 scenarios at B=2 -> 3 chunks, the last one padded
CELLS = [(0, 0), (1, 7), (5, 13), (3, 5), (4, 9)]
WLS = [SUITE.build(mi, ri) for mi, ri in CELLS]
B = 2
N_CHUNKS = 3

ALL_MODES = [sim.MODE_LUT, sim.MODE_ETF, sim.MODE_ETF_IDEAL, sim.MODE_DAS,
             sim.MODE_ORACLE, sim.MODE_THRESHOLD]

# no sleeping in unit tests
FAST = camp.RetryPolicy(backoff_base_s=0.0, backoff_max_s=0.0,
                        jitter_frac=0.0)


def _tree():
    import jax.numpy as jnp
    return sim.DTree(feat=jnp.array([sim.FEAT_RATE, 1, 1], jnp.int32),
                     thr=jnp.array([500.0, 4.0, 6.0], jnp.float32),
                     leaf=jnp.array([0, 1, 0, 1], jnp.int32))


def _mode_kw(mode):
    kw = {}
    if mode == sim.MODE_DAS:
        kw["tree"] = _tree()
    if mode == sim.MODE_THRESHOLD:
        kw["rate_threshold"] = 500.0
    return kw


def _assert_bit_exact(ref: sim.SimResult, out: sim.SimResult, ctx=""):
    for name in sim.SimResult._fields:
        a, b = np.asarray(getattr(ref, name)), np.asarray(getattr(out, name))
        assert a.dtype == b.dtype and a.shape == b.shape, (ctx, name)
        assert a.tobytes() == b.tobytes(), (ctx, name, a, b)


class _Killed(Exception):
    """Stand-in for SIGKILL: not OOM, not a timeout -> never retried."""


def _kill_after(monkeypatch, k: int):
    """Patch the chunk compute to die (non-retryably) after k chunks."""
    real = camp._compute_chunk
    seen = {"n": 0}

    def bomb(*a, **kw):
        if seen["n"] >= k:
            raise _Killed(f"killed after {k} chunks")
        seen["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(camp, "_compute_chunk", bomb)
    return lambda: monkeypatch.setattr(camp, "_compute_chunk", real)


# ---------------------------------------------------------------------------
# the headline invariant: kill -> resume == one uninterrupted sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ALL_MODES)
def test_kill_resume_bit_exact_all_modes(mode, tmp_path, monkeypatch):
    kw = _mode_kw(mode)
    ref = sim.run_batch(mode, WLS, PARAMS, batch_size=B, **kw)

    unkill = _kill_after(monkeypatch, 2)
    with pytest.raises(_Killed):
        camp.run_campaign(mode, WLS, PARAMS, batch_size=B,
                          checkpoint_dir=str(tmp_path), retry=FAST, **kw)
    unkill()

    out = camp.run_campaign(mode, WLS, PARAMS, batch_size=B,
                            checkpoint_dir=str(tmp_path), retry=FAST, **kw)
    assert out.stats["chunks_reused"] == 2, out.stats
    assert out.stats["chunks_computed"] == N_CHUNKS - 2, out.stats
    _assert_bit_exact(ref, out.result, ctx=f"mode {mode}")


def test_kill_resume_bit_exact_stacked_fault_plans(tmp_path, monkeypatch):
    """The same invariant with a per-scenario FaultPlan riding the
    scenario axis (chunk slicing must slice the plan too)."""
    plans = flt.stack_plans([flt.random_plan(s, deadline_us=3000.0)
                             for s in range(len(WLS))])
    ref = sim.run_batch(sim.MODE_LUT, WLS, PARAMS, plan=plans, batch_size=B)

    unkill = _kill_after(monkeypatch, 1)
    with pytest.raises(_Killed):
        camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, plan=plans,
                          batch_size=B, checkpoint_dir=str(tmp_path),
                          retry=FAST)
    unkill()

    out = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, plan=plans,
                            batch_size=B, checkpoint_dir=str(tmp_path),
                            retry=FAST)
    assert out.stats["chunks_reused"] == 1, out.stats
    _assert_bit_exact(ref, out.result, ctx="stacked plans")


def test_packed_kill_resume_bit_exact(tmp_path, monkeypatch):
    """Length-aware packing (PR-10): chunks hold scenarios in predicted-
    length order, the permutation rides the manifest, and a killed packed
    campaign resumes to the same unscattered grid-order result."""
    ref = sim.run_batch(sim.MODE_ETF, WLS, PARAMS, batch_size=B)

    unkill = _kill_after(monkeypatch, 2)
    with pytest.raises(_Killed):
        camp.run_campaign(sim.MODE_ETF, WLS, PARAMS, batch_size=B,
                          checkpoint_dir=str(tmp_path), retry=FAST,
                          pack=True)
    unkill()

    out = camp.run_campaign(sim.MODE_ETF, WLS, PARAMS, batch_size=B,
                            checkpoint_dir=str(tmp_path), retry=FAST,
                            pack=True)
    assert out.stats["packed"] is True
    assert out.stats["chunks_reused"] == 2, out.stats
    assert out.stats["chunks_computed"] == N_CHUNKS - 2, out.stats
    _assert_bit_exact(ref, out.result, ctx="packed kill-resume")
    # the manifest records the (descending predicted-length) permutation
    [cdir] = [d for d in tmp_path.iterdir() if d.is_dir()]
    man = json.loads((cdir / camp.MANIFEST_NAME).read_text())
    pred = camp.predicted_events(
        workloads.stack_workloads(WLS))
    assert sorted(man["perm"]) == list(range(len(WLS)))
    assert list(np.asarray(pred)[man["perm"]]) == \
        sorted(pred, reverse=True)
    # occupancy telemetry covers the computed chunk(s)
    assert out.stats["lane_trips"] > 0
    assert 0 < out.stats["occupancy"] <= 1.0


def test_pack_knob_and_env_opt_out(monkeypatch):
    """pack=False / REPRO_BENCH_PACK=0 keep grid order; either way the
    unscattered result is bit-exact vs run_batch."""
    ref = sim.run_batch(sim.MODE_LUT, WLS, PARAMS, batch_size=B)
    packed = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                               retry=FAST, pack=True)
    plain = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                              retry=FAST, pack=False)
    assert packed.stats["packed"] is True
    assert plain.stats["packed"] is False
    _assert_bit_exact(ref, packed.result, ctx="packed")
    _assert_bit_exact(ref, plain.result, ctx="unpacked")
    # packing may only help: never more allocated lane-iterations
    assert packed.stats["lane_trips"] <= plain.stats["lane_trips"]
    monkeypatch.setenv("REPRO_BENCH_PACK", "0")
    env_off = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                                retry=FAST)
    assert env_off.stats["packed"] is False
    _assert_bit_exact(ref, env_off.result, ctx="env opt-out")


def test_pack_mismatch_resume_recomputes(tmp_path):
    """Chunks checkpointed under one packing order must not be reused by
    a campaign scheduling a different order (the manifest's perm
    mismatches, so the old chunks are dropped)."""
    camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                      checkpoint_dir=str(tmp_path), retry=FAST, pack=True)
    ref = sim.run_batch(sim.MODE_LUT, WLS, PARAMS, batch_size=B)
    out = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                            checkpoint_dir=str(tmp_path), retry=FAST,
                            pack=False)
    assert out.stats["chunks_reused"] == 0, out.stats
    assert out.stats["chunks_computed"] == N_CHUNKS, out.stats
    _assert_bit_exact(ref, out.result, ctx="pack-mismatch resume")


def test_predicted_events_shape_and_monotonicity():
    """The predictor is `3 * n_tasks + n_insts` (the engine's own
    max_iters shape): more tasks at the same instance count must never
    predict shorter."""
    stacked = workloads.stack_workloads(WLS)
    pred = camp.predicted_events(stacked)
    assert pred.shape == (len(WLS),)
    expect = 3 * np.asarray(stacked.n_tasks, np.int64) \
        + np.asarray(stacked.n_insts, np.int64)
    np.testing.assert_array_equal(pred, expect)


def test_uncheckpointed_campaign_matches_run_batch():
    """Without a checkpoint dir the campaign is run_batch + stats."""
    ref = sim.run_batch(sim.MODE_ETF, WLS, PARAMS, batch_size=B)
    out = camp.run_campaign(sim.MODE_ETF, WLS, PARAMS, batch_size=B,
                            retry=FAST)
    assert out.stats["n_chunks"] == N_CHUNKS
    assert out.stats["chunks_computed"] == N_CHUNKS
    _assert_bit_exact(ref, out.result)


def test_full_resume_reuses_every_chunk(tmp_path):
    first = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                              checkpoint_dir=str(tmp_path), retry=FAST)
    again = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                              checkpoint_dir=str(tmp_path), retry=FAST)
    assert again.stats["chunks_reused"] == N_CHUNKS
    assert again.stats["chunks_computed"] == 0
    _assert_bit_exact(first.result, again.result)
    # resume=False recomputes but must not change anything
    fresh = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                              checkpoint_dir=str(tmp_path), retry=FAST,
                              resume=False)
    assert fresh.stats["chunks_computed"] == N_CHUNKS
    _assert_bit_exact(first.result, fresh.result)


def test_corrupt_chunk_is_recomputed(tmp_path):
    first = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                              checkpoint_dir=str(tmp_path), retry=FAST)
    [cdir] = [d for d in tmp_path.iterdir() if d.is_dir()]
    victim = cdir / "chunk_00001.npz"
    victim.write_bytes(b"not an npz file")
    out = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                            checkpoint_dir=str(tmp_path), retry=FAST)
    assert out.stats["chunks_reused"] == N_CHUNKS - 1, out.stats
    assert out.stats["chunks_computed"] == 1, out.stats
    _assert_bit_exact(first.result, out.result)


def test_different_spec_does_not_share_checkpoints(tmp_path):
    """Changing anything that affects results (here: the mode) must miss
    the checkpoint, not silently reuse the wrong chunks."""
    camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                      checkpoint_dir=str(tmp_path), retry=FAST)
    out = camp.run_campaign(sim.MODE_ETF, WLS, PARAMS, batch_size=B,
                            checkpoint_dir=str(tmp_path), retry=FAST)
    assert out.stats["chunks_reused"] == 0
    ref = sim.run_batch(sim.MODE_ETF, WLS, PARAMS, batch_size=B)
    _assert_bit_exact(ref, out.result)


def test_stale_manifest_drops_old_chunks(tmp_path, monkeypatch):
    camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                      checkpoint_dir=str(tmp_path), retry=FAST)
    [cdir] = [d for d in tmp_path.iterdir() if d.is_dir()]
    mpath = cdir / camp.MANIFEST_NAME
    stale = json.loads(mpath.read_text())
    stale["version"] = camp.FORMAT_VERSION - 1
    mpath.write_text(json.dumps(stale))
    # same spec, but the manifest no longer matches -> chunks dropped
    out = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                            checkpoint_dir=str(tmp_path), retry=FAST)
    assert out.stats["chunks_reused"] == 0
    assert out.stats["chunks_computed"] == N_CHUNKS


# ---------------------------------------------------------------------------
# failure injection: OOM shrink, watchdog, step-budget escalation
# ---------------------------------------------------------------------------
def test_forced_oom_shrinks_and_completes(monkeypatch):
    """RESOURCE_EXHAUSTED above batch 1 -> halving retries down to
    single-scenario sub-chunks, final grid complete and bit-exact."""
    ref = sim.run_batch(sim.MODE_LUT, WLS, PARAMS, batch_size=B)
    real = camp._compute_chunk

    def oomy(mode, part, params, tree, rt, plan, batch, devices, budget,
             **kw):
        if batch > 1:
            raise RuntimeError("RESOURCE_EXHAUSTED: out of memory "
                               "allocating 1.21GB")
        return real(mode, part, params, tree, rt, plan, batch, devices,
                    budget, **kw)

    monkeypatch.setattr(camp, "_compute_chunk", oomy)
    out = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                            retry=FAST)
    assert out.stats["oom_events"] == N_CHUNKS, out.stats
    assert out.stats["shrinks"] == N_CHUNKS, out.stats
    assert out.stats["retries"] == N_CHUNKS, out.stats
    _assert_bit_exact(ref, out.result, ctx="post-shrink")


def test_oom_exhaustion_raises_campaign_error(monkeypatch):
    monkeypatch.setattr(
        camp, "_compute_chunk",
        lambda *a, **k: (_ for _ in ()).throw(
            RuntimeError("RESOURCE_EXHAUSTED: out of memory")))
    with pytest.raises(camp.CampaignError, match="gave up after 2 attempts"):
        camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                          retry=camp.RetryPolicy(
                              max_retries=1, backoff_base_s=0.0,
                              backoff_max_s=0.0, jitter_frac=0.0))


def test_unrecognized_exception_propagates(monkeypatch):
    """Bugs are not infrastructure weather: no retry, no swallowing."""
    monkeypatch.setattr(
        camp, "_compute_chunk",
        lambda *a, **k: (_ for _ in ()).throw(ValueError("a real bug")))
    with pytest.raises(ValueError, match="a real bug"):
        camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                          retry=FAST)


def test_watchdog_trips_then_retry_succeeds(monkeypatch):
    ref = sim.run_batch(sim.MODE_LUT, WLS, PARAMS, batch_size=B)
    real = camp._compute_chunk
    slow = {"left": 1}

    def sleepy(*a, **kw):
        if slow["left"]:
            slow["left"] -= 1
            time.sleep(0.6)
        return real(*a, **kw)

    monkeypatch.setattr(camp, "_compute_chunk", sleepy)
    out = camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                            watchdog_s=0.15, retry=FAST)
    assert out.stats["timeouts"] >= 1, out.stats
    assert out.stats["retries"] >= 1, out.stats
    _assert_bit_exact(ref, out.result, ctx="post-watchdog")


def test_step_budget_trip_escalates_and_completes():
    """A starvation-level step budget trips `STALL_BUDGET`, the retry
    escalates it x`budget_escalation`, and the campaign still converges
    to the unbudgeted result."""
    ref = sim.run_batch(sim.MODE_LUT, WLS, PARAMS, batch_size=B)
    out = camp.run_campaign(
        sim.MODE_LUT, WLS, PARAMS, batch_size=B, step_budget=8,
        retry=camp.RetryPolicy(max_retries=6, backoff_base_s=0.0,
                               backoff_max_s=0.0, jitter_frac=0.0))
    assert out.stats["stall_trips"] >= 1, out.stats
    assert (np.asarray(out.result.stall_reason) == sim.STALL_NONE).all()
    _assert_bit_exact(ref, out.result, ctx="post-escalation")


def test_step_budget_surfaces_stall_reason():
    """Without the campaign's escalation, a tripped budget is visible as
    `STALL_BUDGET` in both the sequential and batched engines."""
    r = sim.run(sim.MODE_LUT, WLS[0], PARAMS, step_budget=8)
    assert int(r.stall_reason) == sim.STALL_BUDGET
    assert int(r.n_done) < int(np.asarray(WLS[0].task_type).shape[0])
    rb = sim.run_batch(sim.MODE_LUT, WLS, PARAMS, batch_size=B,
                       step_budget=8)
    assert (np.asarray(rb.stall_reason) == sim.STALL_BUDGET).all()
    # a generous budget changes nothing
    r0 = sim.run(sim.MODE_LUT, WLS[0], PARAMS)
    r1 = sim.run(sim.MODE_LUT, WLS[0], PARAMS, step_budget=10_000_000)
    assert int(r1.stall_reason) == sim.STALL_NONE
    _assert_bit_exact(r0, r1, ctx="generous budget")


# ---------------------------------------------------------------------------
# small pieces: geometry, atomic writes, policy math
# ---------------------------------------------------------------------------
def test_shrink_batch_respects_device_multiple_and_floor():
    assert camp._shrink_batch(8, 1, 1) == 4
    assert camp._shrink_batch(2, 1, 1) == 1
    assert camp._shrink_batch(1, 1, 1) == 1   # already at the floor
    assert camp._shrink_batch(8, 4, 1) == 4   # stays a device multiple
    assert camp._shrink_batch(4, 4, 1) == 4
    assert camp._shrink_batch(16, 1, 4) == 8
    assert camp._shrink_batch(8, 1, 4) == 4   # clamped at floor * D


def test_backoff_is_seeded_and_capped():
    pol = camp.RetryPolicy(backoff_base_s=1.0, backoff_factor=2.0,
                           backoff_max_s=3.0, jitter_frac=0.5, seed=7)
    a = [pol.backoff_s(k, np.random.RandomState(pol.seed)) for k in range(4)]
    b = [pol.backoff_s(k, np.random.RandomState(pol.seed)) for k in range(4)]
    assert a == b                       # reproducible
    assert all(x <= 3.0 * 1.5 for x in a)   # capped (+jitter)
    assert a[1] >= a[0]                 # growing until the cap


def test_atomic_write_json(tmp_path):
    path = str(tmp_path / "out.json")
    camp.atomic_write_json(path, {"a": 1})
    camp.atomic_write_json(path, {"a": 2, "arr": np.int64(3)},
                           default=lambda o: int(o))
    with open(path) as f:
        assert json.load(f) == {"a": 2, "arr": 3}
    assert not os.path.exists(path + ".tmp")


def test_spec_hash_sensitivity():
    stacked = workloads.stack_workloads(WLS)
    stacked = workloads.FlatWorkload(*[np.asarray(f) for f in stacked])
    tree = type(_tree())(*[np.asarray(f) for f in _tree()])
    h = lambda mode, thr: camp.spec_hash(  # noqa: E731
        mode, stacked, PARAMS, tree, np.asarray(thr, np.float32), None)
    assert h(sim.MODE_LUT, 500.0) == h(sim.MODE_LUT, 500.0)
    assert h(sim.MODE_LUT, 500.0) != h(sim.MODE_ETF, 500.0)
    assert h(sim.MODE_LUT, 500.0) != h(sim.MODE_LUT, 600.0)


def test_batch_size_validation():
    with pytest.raises(ValueError, match="positive"):
        camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, batch_size=0)


def test_batched_plan_length_mismatch():
    plans = flt.stack_plans([flt.random_plan(s) for s in range(2)])
    with pytest.raises(ValueError, match="2 scenarios"):
        camp.run_campaign(sim.MODE_LUT, WLS, PARAMS, plan=plans,
                          batch_size=B)


# ---------------------------------------------------------------------------
# benchmarks.common satellites: autotune cache + health naming
# ---------------------------------------------------------------------------
@pytest.fixture()
def bench_common(tmp_path, monkeypatch):
    common = pytest.importorskip("benchmarks.common")
    monkeypatch.setenv("REPRO_BENCH_CACHE_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_BENCH_BATCH", raising=False)
    common.batch_size.cache_clear()
    yield common
    common.batch_size.cache_clear()


def test_autotune_cache_roundtrip(bench_common, monkeypatch):
    common = bench_common
    monkeypatch.setattr(common, "_probe_batch_size", lambda backend: 12)
    assert common.batch_size() == 12
    with open(common._autotune_cache_path()) as f:
        assert f.read().count("12")
    # second process (simulated): the cache must answer without probing
    common.batch_size.cache_clear()
    monkeypatch.setattr(
        common, "_probe_batch_size",
        lambda backend: pytest.fail("probe ran despite a warm cache"))
    assert common.batch_size() == 12


def test_autotune_cache_corrupt_file_reprobes(bench_common, monkeypatch):
    common = bench_common
    with open(common._autotune_cache_path(), "w") as f:
        f.write("{ not json")
    monkeypatch.setattr(common, "_probe_batch_size", lambda backend: 24)
    assert common.batch_size() == 24
    with open(common._autotune_cache_path()) as f:
        cache = json.load(f)          # re-written, valid again
    assert common._autotune_key() in cache


def test_autotune_cache_stale_key_misses(bench_common, monkeypatch):
    common = bench_common
    camp.atomic_write_json(common._autotune_cache_path(),
                           {"tpu|dev8|jax9.9.9": 256})
    monkeypatch.setattr(common, "_probe_batch_size", lambda backend: 8)
    assert common.batch_size() == 8   # stale entry ignored, not trusted
    with open(common._autotune_cache_path()) as f:
        cache = json.load(f)
    assert cache["tpu|dev8|jax9.9.9"] == 256   # foreign entries preserved


def test_env_batch_overrides_cache(bench_common, monkeypatch):
    common = bench_common
    monkeypatch.setenv("REPRO_BENCH_BATCH", "6")
    monkeypatch.setattr(
        common, "_probe_batch_size",
        lambda backend: pytest.fail("probe ran despite REPRO_BENCH_BATCH"))
    assert common.batch_size() == 6


def _fake_result(stalled=False, stall_reason=sim.STALL_NONE, jobs=0,
                 tasks=0):
    return types.SimpleNamespace(
        stalled=np.bool_(stalled), stall_reason=np.int32(stall_reason),
        n_dropped_jobs=np.int32(jobs), n_dropped_tasks=np.int32(tasks))


def test_report_health_names_offending_scenarios(capsys):
    common = pytest.importorskip("benchmarks.common")
    results = [_fake_result(),
               _fake_result(stalled=True,
                            stall_reason=sim.STALL_DEADLOCK),
               _fake_result(stall_reason=sim.STALL_BUDGET),
               _fake_result(jobs=3, tasks=7)]
    cells = [(0, 0), (1, 7), (5, 13), (3, 5)]
    health = common.report_health(results, label="unit", cells=cells)
    assert health["stalled_cells"] == 2
    assert health["dropped_jobs"] == 3 and health["dropped_tasks"] == 7
    assert health["stalled_at"] == [(1, (1, 7), "deadlock"),
                                    (2, (5, 13), "step-budget")]
    assert health["dropped_at"] == [(3, (3, 5), 3, 7)]
    out = capsys.readouterr().out
    assert "scenario 1" in out and "(mix, rate)=(1, 7)" in out
    assert "step-budget" in out
    assert "scenario 3" in out and "jobs=3" in out


def test_report_health_clean_sweep_is_quiet(capsys):
    common = pytest.importorskip("benchmarks.common")
    health = common.report_health([_fake_result()] * 3, label="unit")
    assert health["stalled_at"] == [] and health["dropped_at"] == []
    assert capsys.readouterr().out == ""

"""Optional-`hypothesis` shim for the test suite.

The seed state hard-imported `hypothesis` at the top of three test modules,
so `python -m pytest -x -q` died with collection ImportErrors on minimal
environments. Importing `hypothesis`/`st` from here instead keeps every
unit test collectable and running; only the property tests degrade — to a
clean per-test skip — when the package is missing.
"""
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Stand-in for `hypothesis.strategies`: any strategy builds None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    class _Hypothesis:
        """Stand-in decorators: `@given` turns the test into a skip."""

        @staticmethod
        def settings(*a, **k):
            return lambda fn: fn

        @staticmethod
        def given(*a, **k):
            def deco(fn):
                def skipper():
                    pytest.skip("hypothesis not installed")

                # keep the collected test name; no __wrapped__ so pytest
                # sees the zero-arg signature, not the original's params
                skipper.__name__ = fn.__name__
                skipper.__doc__ = fn.__doc__
                return skipper

            return deco

    hypothesis = _Hypothesis()
    st = _Strategies()

"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles,
interpret mode (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hyp_compat import hypothesis, st
from repro.kernels.etf_ft import kernel as etfk, ref as etfr
from repro.kernels.flash_attention import kernel as fak, ref as far
from repro.kernels.rg_lru import kernel as rgk, ref as rgr
from repro.kernels.ssd_scan import kernel as ssdk, ref as ssdr


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # B, S, H, K, D, window, softcap, dtype
    (1, 256, 4, 4, 64, 0, 0.0, "float32"),     # MHA
    (2, 256, 8, 2, 64, 0, 0.0, "float32"),     # GQA
    (1, 256, 4, 1, 128, 0, 0.0, "float32"),    # MQA, d128
    (1, 512, 4, 2, 64, 128, 0.0, "float32"),   # sliding window
    (1, 256, 4, 4, 64, 0, 30.0, "float32"),    # softcap
    (2, 256, 8, 2, 64, 0, 0.0, "bfloat16"),    # bf16
    (1, 384, 6, 3, 32, 0, 0.0, "float32"),     # non-128 block tail (S=384)
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_vs_oracle(case):
    B, S, H, K, D, W, cap, dt = case
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D), dt)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, D), dt)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, D), dt)
    out = fak.flash_attention_fwd(q, k, v, causal=True, window=W,
                                  softcap=cap, block_q=128, block_k=128,
                                  interpret=True)
    expect = far.mha_reference(q, k, v, causal=True, window=W, softcap=cap)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - expect.astype(jnp.float32))))
    tol = 2e-2 if dt == "bfloat16" else 1e-4
    assert err < tol, (case, err)


def test_flash_block_shape_sweep():
    B, S, H, K, D = 1, 256, 2, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, D))
    expect = far.mha_reference(q, k, v)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        out = fak.flash_attention_fwd(q, k, v, block_q=bq, block_k=bk,
                                      interpret=True)
        assert float(jnp.max(jnp.abs(out - expect))) < 1e-4, (bq, bk)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [
    (1, 32, 2, 8, 4, 16), (2, 64, 3, 16, 8, 16), (1, 128, 2, 16, 16, 32),
])
def test_ssd_vs_sequential_oracle(shape):
    B, S, H, P, N, Q = shape
    ks = [jax.random.PRNGKey(i) for i in range(5)]
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bh = jax.random.normal(ks[3], (B, S, H, N)) * 0.5
    Ch = jax.random.normal(ks[4], (B, S, H, N)) * 0.5
    y, h = ssdk.ssd_fwd(x, dt, A, Bh, Ch, chunk=Q, interpret=True)
    y2, h2 = ssdr.ssd_reference(x, dt, A, Bh, Ch)
    assert float(jnp.max(jnp.abs(y - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(h - h2))) < 1e-4


def test_ssd_bf16_tolerance():
    B, S, H, P, N, Q = 1, 64, 2, 16, 8, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, P),
                          jnp.bfloat16) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (B, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)))
    Bh = (jax.random.normal(jax.random.PRNGKey(3), (B, S, H, N)) * 0.5)
    Ch = (jax.random.normal(jax.random.PRNGKey(4), (B, S, H, N)) * 0.5)
    y, _ = ssdk.ssd_fwd(x, dt, A, Bh, Ch, chunk=Q, interpret=True)
    y2, _ = ssdr.ssd_reference(x.astype(jnp.float32), dt, A, Bh, Ch)
    rel = float(jnp.max(jnp.abs(y.astype(jnp.float32) - y2))) / (
        float(jnp.max(jnp.abs(y2))) + 1e-9)
    assert rel < 3e-2


# ---------------------------------------------------------------------------
# rg-lru scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1, 32, 128), (2, 64, 256), (1, 96, 384)])
def test_rg_lru_vs_oracle(shape):
    B, S, C = shape
    a = jax.random.uniform(jax.random.PRNGKey(0), (B, S, C),
                           minval=0.6, maxval=0.999)
    b = jax.random.normal(jax.random.PRNGKey(1), (B, S, C)) * 0.1
    out = rgk.rg_lru_fwd(a, b, chunk=16, block_c=128, interpret=True)
    expect = rgr.rg_lru_reference(a, b)
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-5


# ---------------------------------------------------------------------------
# etf finish-time search
# ---------------------------------------------------------------------------
@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(seed=st.integers(0, 1000), b=st.integers(1, 8),
                  r=st.integers(2, 32))
def test_etf_kernel_property(seed, b, r):
    P = 19
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    avail = jax.random.uniform(ks[0], (b, r, P)) * 10
    free = jax.random.uniform(ks[1], (b, P)) * 10
    ex = jnp.where(jax.random.uniform(ks[2], (b, r, P)) < 0.3, jnp.inf,
                   jax.random.uniform(ks[3], (b, r, P)) * 5)
    now = jnp.zeros((b,))
    ft1, s1, p1 = etfk.etf_ft_search(avail, free, ex, now, interpret=True)
    ft2, s2, p2 = etfr.etf_ft_reference(avail, free, ex, now)
    np.testing.assert_allclose(np.asarray(ft1), np.asarray(ft2), rtol=1e-6)
    assert (np.asarray(s1) == np.asarray(s2)).all()
    assert (np.asarray(p1) == np.asarray(p2)).all()


def test_etf_kernel_min_is_achievable():
    """The returned (slot, pe) must actually achieve the returned FT."""
    b, r, P = 3, 8, 19
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    avail = jax.random.uniform(ks[0], (b, r, P)) * 10
    free = jax.random.uniform(ks[1], (b, P)) * 10
    ex = jax.random.uniform(ks[2], (b, r, P)) * 5
    now = jnp.zeros((b,))
    ft, s, p = etfk.etf_ft_search(avail, free, ex, now, interpret=True)
    for i in range(b):
        si, pi = int(s[i]), int(p[i])
        direct = max(float(avail[i, si, pi]), float(free[i, pi]), 0.0) \
            + float(ex[i, si, pi])
        assert abs(direct - float(ft[i])) < 1e-5

"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles,
interpret mode (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hyp_compat import hypothesis, st
from repro.kernels.etf_ft import kernel as etfk, ops as etfo, ref as etfr
from repro.kernels.flash_attention import kernel as fak, ref as far
from repro.kernels.rg_lru import kernel as rgk, ref as rgr
from repro.kernels.ssd_scan import kernel as ssdk, ref as ssdr


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # B, S, H, K, D, window, softcap, dtype
    (1, 256, 4, 4, 64, 0, 0.0, "float32"),     # MHA
    (2, 256, 8, 2, 64, 0, 0.0, "float32"),     # GQA
    (1, 256, 4, 1, 128, 0, 0.0, "float32"),    # MQA, d128
    (1, 512, 4, 2, 64, 128, 0.0, "float32"),   # sliding window
    (1, 256, 4, 4, 64, 0, 30.0, "float32"),    # softcap
    (2, 256, 8, 2, 64, 0, 0.0, "bfloat16"),    # bf16
    (1, 384, 6, 3, 32, 0, 0.0, "float32"),     # non-128 block tail (S=384)
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_vs_oracle(case):
    B, S, H, K, D, W, cap, dt = case
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D), dt)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, D), dt)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, D), dt)
    out = fak.flash_attention_fwd(q, k, v, causal=True, window=W,
                                  softcap=cap, block_q=128, block_k=128,
                                  interpret=True)
    expect = far.mha_reference(q, k, v, causal=True, window=W, softcap=cap)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - expect.astype(jnp.float32))))
    tol = 2e-2 if dt == "bfloat16" else 1e-4
    assert err < tol, (case, err)


def test_flash_block_shape_sweep():
    B, S, H, K, D = 1, 256, 2, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, D))
    expect = far.mha_reference(q, k, v)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        out = fak.flash_attention_fwd(q, k, v, block_q=bq, block_k=bk,
                                      interpret=True)
        assert float(jnp.max(jnp.abs(out - expect))) < 1e-4, (bq, bk)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [
    (1, 32, 2, 8, 4, 16), (2, 64, 3, 16, 8, 16), (1, 128, 2, 16, 16, 32),
])
def test_ssd_vs_sequential_oracle(shape):
    B, S, H, P, N, Q = shape
    ks = [jax.random.PRNGKey(i) for i in range(5)]
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bh = jax.random.normal(ks[3], (B, S, H, N)) * 0.5
    Ch = jax.random.normal(ks[4], (B, S, H, N)) * 0.5
    y, h = ssdk.ssd_fwd(x, dt, A, Bh, Ch, chunk=Q, interpret=True)
    y2, h2 = ssdr.ssd_reference(x, dt, A, Bh, Ch)
    assert float(jnp.max(jnp.abs(y - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(h - h2))) < 1e-4


def test_ssd_bf16_tolerance():
    B, S, H, P, N, Q = 1, 64, 2, 16, 8, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, P),
                          jnp.bfloat16) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (B, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)))
    Bh = (jax.random.normal(jax.random.PRNGKey(3), (B, S, H, N)) * 0.5)
    Ch = (jax.random.normal(jax.random.PRNGKey(4), (B, S, H, N)) * 0.5)
    y, _ = ssdk.ssd_fwd(x, dt, A, Bh, Ch, chunk=Q, interpret=True)
    y2, _ = ssdr.ssd_reference(x.astype(jnp.float32), dt, A, Bh, Ch)
    rel = float(jnp.max(jnp.abs(y.astype(jnp.float32) - y2))) / (
        float(jnp.max(jnp.abs(y2))) + 1e-9)
    assert rel < 3e-2


# ---------------------------------------------------------------------------
# rg-lru scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1, 32, 128), (2, 64, 256), (1, 96, 384)])
def test_rg_lru_vs_oracle(shape):
    B, S, C = shape
    a = jax.random.uniform(jax.random.PRNGKey(0), (B, S, C),
                           minval=0.6, maxval=0.999)
    b = jax.random.normal(jax.random.PRNGKey(1), (B, S, C)) * 0.1
    out = rgk.rg_lru_fwd(a, b, chunk=16, block_c=128, interpret=True)
    expect = rgr.rg_lru_reference(a, b)
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-5


# ---------------------------------------------------------------------------
# etf finish-time search
# ---------------------------------------------------------------------------
@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(seed=st.integers(0, 1000), b=st.integers(1, 8),
                  r=st.integers(2, 32))
def test_etf_kernel_property(seed, b, r):
    P = 19
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    avail = jax.random.uniform(ks[0], (b, r, P)) * 10
    free = jax.random.uniform(ks[1], (b, P)) * 10
    ex = jnp.where(jax.random.uniform(ks[2], (b, r, P)) < 0.3, jnp.inf,
                   jax.random.uniform(ks[3], (b, r, P)) * 5)
    now = jnp.zeros((b,))
    ft1, s1, p1 = etfk.etf_ft_search(avail, free, ex, now, interpret=True)
    ft2, s2, p2 = etfr.etf_ft_reference(avail, free, ex, now)
    np.testing.assert_allclose(np.asarray(ft1), np.asarray(ft2), rtol=1e-6)
    assert (np.asarray(s1) == np.asarray(s2)).all()
    assert (np.asarray(p1) == np.asarray(p2)).all()


def test_etf_kernel_min_is_achievable():
    """The returned (slot, pe) must actually achieve the returned FT."""
    b, r, P = 3, 8, 19
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    avail = jax.random.uniform(ks[0], (b, r, P)) * 10
    free = jax.random.uniform(ks[1], (b, P)) * 10
    ex = jax.random.uniform(ks[2], (b, r, P)) * 5
    now = jnp.zeros((b,))
    ft, s, p = etfk.etf_ft_search(avail, free, ex, now, interpret=True)
    for i in range(b):
        si, pi = int(s[i]), int(p[i])
        direct = max(float(avail[i, si, pi]), float(free[i, pi]), 0.0) \
            + float(ex[i, si, pi])
        assert abs(direct - float(ft[i])) < 1e-5


# ---------------------------------------------------------------------------
# masked decision search + push rows (PR-10, the simulator hot path)
# ---------------------------------------------------------------------------
def _masked_case(seed, s, r, tie_frac=0.0):
    P = 19
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    avail = jax.random.uniform(ks[0], (s, r, P)) * 10
    free = jax.random.uniform(ks[1], (s, P)) * 10
    ex = jnp.where(jax.random.uniform(ks[2], (s, r, P)) < 0.3, jnp.inf,
                   jax.random.uniform(ks[3], (s, r, P)) * 5)
    if tie_frac:
        # quantize hard so many (slot, pe) pairs tie for the minimum —
        # the tie-break (first flat index) is the contract under test
        avail = jnp.round(avail / 5) * 5
        free = jnp.round(free / 5) * 5
        ex = jnp.round(ex)
    now = jax.random.uniform(ks[4], (s,)) * 3
    slot_ok = jax.random.uniform(ks[5], (s, r)) < 0.7
    alive = jax.random.uniform(ks[6], (s, P)) < 0.8
    return avail, free, ex, now, slot_ok, alive


def _masked_oracle(avail, free, ex, now, slot_ok, alive):
    """Inline numpy restatement of the simulator's masked argmin."""
    a, f, e = np.asarray(avail), np.asarray(free), np.asarray(ex)
    ft = np.maximum(np.maximum(a, f[:, None, :]),
                    np.asarray(now)[:, None, None]) + e
    ok = (np.asarray(slot_ok)[:, :, None] & np.asarray(alive)[:, None, :]
          & np.isfinite(ft))
    ft = np.where(ok, ft, etfk.BIG).astype(np.float32)
    S, R, P = ft.shape
    flat = ft.reshape(S, -1)
    idx = flat.argmin(1)
    mn = flat[np.arange(S), idx]
    return mn, idx // P, idx % P, mn < etfk.BIG


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(seed=st.integers(0, 1000), s=st.integers(1, 6),
                  r=st.integers(2, 24), ties=st.booleans())
def test_etf_masked_kernel_property(seed, s, r, ties):
    case = _masked_case(seed, s, r, tie_frac=1.0 if ties else 0.0)
    ft1, s1, p1, ok1 = etfk.etf_ft_search_masked(*case, interpret=True)
    ft2, s2, p2, ok2 = etfr.etf_ft_masked_reference(*case)
    ft3, s3, p3, ok3 = _masked_oracle(*case)
    for tag, (ft, sl, pe, ok) in (("kernel", (ft1, s1, p1, ok1)),
                                  ("xla", (ft2, s2, p2, ok2))):
        assert np.asarray(ft).tobytes() == ft3.tobytes(), tag
        assert (np.asarray(sl) == s3).all(), tag
        assert (np.asarray(pe) == p3).all(), tag
        assert (np.asarray(ok) == ok3).all(), tag


def test_etf_masked_all_masked_lane():
    """Everything masked -> slot 0 / pe 0, feasible False on both paths
    (the simulator relies on this to fall back to its own no-op)."""
    s, r, P = 2, 4, 19
    avail = jnp.ones((s, r, P))
    free = jnp.zeros((s, P))
    ex = jnp.ones((s, r, P))
    now = jnp.zeros((s,))
    slot_ok = jnp.zeros((s, r), bool)
    alive = jnp.ones((s, P), bool)
    for fn in (lambda: etfk.etf_ft_search_masked(
                   avail, free, ex, now, slot_ok, alive, interpret=True),
               lambda: etfr.etf_ft_masked_reference(
                   avail, free, ex, now, slot_ok, alive)):
        _, sl, pe, ok = fn()
        assert (np.asarray(sl) == 0).all() and (np.asarray(pe) == 0).all()
        assert not np.asarray(ok).any()


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(seed=st.integers(0, 1000), s=st.integers(1, 4),
                  k=st.integers(1, 8), mp=st.integers(1, 6))
def test_push_rows_kernel_vs_naive(seed, s, k, mp):
    P, C = 19, 6
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    pfin = jax.random.uniform(ks[0], (s, k, mp)) * 100
    cost = jax.random.uniform(ks[1], (s, k, mp)) * 10
    pcl = jax.random.randint(ks[2], (s, k, mp), 0, C)
    pv = jax.random.uniform(ks[3], (s, k, mp)) < 0.6
    pecl = jnp.asarray(np.random.RandomState(seed).randint(0, C, P))
    bases = jax.random.uniform(ks[4], (s, k)) * 50
    # naive [S, K, MP, P] oracle — exactly the simulator's inline max
    cross = (np.asarray(pcl)[..., None]
             != np.asarray(pecl)[None, None, None, :])
    contrib = np.where(np.asarray(pv)[..., None],
                       np.asarray(pfin)[..., None]
                       + np.asarray(cost)[..., None] * cross.astype(
                           np.float32),
                       -np.inf)
    naive = np.maximum(contrib.max(axis=2),
                       np.asarray(bases)[..., None]).astype(np.float32)
    got_k = etfk.push_rows(pfin, cost, pcl, pv, pecl, bases,
                           interpret=True)
    got_r = etfr.push_rows_reference(pfin, cost, pcl, pv, pecl, bases, C)
    np.testing.assert_array_equal(np.asarray(got_r), naive)
    np.testing.assert_array_equal(np.asarray(got_k), naive)


def test_etf_ops_dispatch_counts(monkeypatch):
    """Each `ops` call tallies exactly one dispatch under its backend."""
    case = _masked_case(0, 1, 4)
    single = tuple(x[0] for x in case)
    before = dict(etfo.DISPATCH_COUNT)
    etfo.etf_decide(*single, mode="xla")
    etfo.etf_decide(*single, mode="pallas-interpret")
    assert etfo.DISPATCH_COUNT["etf_xla"] == before["etf_xla"] + 1
    assert etfo.DISPATCH_COUNT["etf_pallas_interpret"] == \
        before["etf_pallas_interpret"] + 1


def test_kernel_mode_resolution(monkeypatch):
    km = etfo.kernel_mode
    assert km("off") == "off" and km("0") == "off"
    assert km("xla") == "xla"
    assert km("pallas-interpret") == "pallas-interpret"
    on_tpu = jax.default_backend() == "tpu"
    assert km("auto") == ("pallas" if on_tpu else "xla")
    assert km("pallas") == ("pallas" if on_tpu else "pallas-interpret")
    # idempotent on resolved modes
    for m in ("off", "xla", "pallas", "pallas-interpret"):
        assert km(km(m)) == km(m)
    monkeypatch.setenv("REPRO_SIM_KERNELS", "off")
    assert km() == "off"
    with pytest.raises(ValueError, match="REPRO_SIM_KERNELS"):
        km("bogus")


def test_interpret_limit_derived_from_block_shape(monkeypatch):
    """The interpret-mode bailout must come from the kernel's block
    geometry (cells budget / per-step block), not a hard-coded batch:
    at the default [64, 19->128] geometry it reproduces the old B > 64."""
    assert etfo.interpret_batch_limit(64, 19) == 64
    # half the rows -> twice the batch; wider PE pad -> proportionally less
    assert etfo.interpret_batch_limit(32, 19) == 128
    assert etfo.interpret_batch_limit(64, 129) == 32
    monkeypatch.setenv("REPRO_ETF_FT_INTERPRET_CELLS", str(64 * 128 * 2))
    assert etfo.interpret_batch_limit(64, 19) == 2


def test_interpret_fallback_boundary_agrees(monkeypatch):
    """`etf_ft` just below the limit (kernel) and just above (jnp ref
    fallback) must agree — the silent-fallback bug was the two paths
    drifting unnoticed."""
    # shrink the budget so the boundary is tiny and cheap to straddle
    monkeypatch.setenv("REPRO_ETF_FT_INTERPRET_CELLS", str(8 * 128 * 2))
    r, P = 8, 19
    limit = etfo.interpret_batch_limit(r, P)
    assert limit == 2
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B = limit + 1
    avail = jax.random.uniform(ks[0], (B, r, P)) * 10
    free = jax.random.uniform(ks[1], (B, P)) * 10
    ex = jax.random.uniform(ks[2], (B, r, P)) * 5
    now = jnp.zeros((B,))
    before = etfo.DISPATCH_COUNT["etf_ft_ref_fallback"]
    # B = limit: kernel path (no fallback tally)
    out_k = etfo.etf_ft(avail[:limit], free[:limit], ex[:limit],
                        now[:limit], interpret=True)
    assert etfo.DISPATCH_COUNT["etf_ft_ref_fallback"] == before
    # B = limit + 1: reference fallback (tallied)
    out_r = etfo.etf_ft(avail, free, ex, now, interpret=True)
    assert etfo.DISPATCH_COUNT["etf_ft_ref_fallback"] == before + 1
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(b)[:limit])

"""Differential test: the jittable lax.while_loop simulator vs an
independently-written pure-Python reference (core/ref_sim.py) must agree on
per-task schedules for LUT / ETF / ETF-ideal across workloads and rates."""
import numpy as np
import pytest

from repro.core import ref_sim, simulator as sim, workloads

SUITE = workloads.default_suite(n_instances=10)
PARAMS = sim.make_params()

CASES = [(mix, rate, mode)
         for mix in (0, 1, 4, 5)
         for rate in (0, 9, 13)
         for mode in (sim.MODE_LUT, sim.MODE_ETF, sim.MODE_ETF_IDEAL)]


@pytest.mark.parametrize("mix,rate,mode", CASES)
def test_jax_sim_matches_reference(mix, rate, mode):
    wl = SUITE.build(mix, rate)
    r_jax = sim.run(mode, wl, PARAMS)
    r_ref = ref_sim.simulate_ref(mode, wl)

    assert int(r_jax.n_done) == r_ref["n_done"]
    nt = int(wl.n_tasks)
    fin_jax = np.asarray(r_jax.finish)[:nt]
    fin_ref = r_ref["finish"][:nt]
    # fp32 sim vs fp64 reference: tight agreement for ~all tasks; exact
    # finish-time ties broken differently may cascade a small bounded
    # deviation into a handful of downstream tasks (comm-cost deltas)
    atol = 1e-3 * max(1.0, float(np.abs(fin_ref).max()))
    diff = np.abs(fin_jax - fin_ref)
    assert (diff <= atol).mean() >= 0.98, diff.max()
    assert diff.max() < 0.25, diff.max()
    # PE assignments: exact except where fp32 vs fp64 breaks an exact
    # finish-time tie differently — matching finish times (asserted above)
    # prove any divergent choice achieved the identical FT, i.e. a tie.
    pe_match = (np.asarray(r_jax.pe_of)[:nt] == r_ref["pe_of"][:nt])
    assert pe_match.mean() > 0.9, pe_match.mean()
    assert float(r_jax.avg_exec_us) == pytest.approx(
        r_ref["avg_exec_us"], rel=1e-4, abs=1e-3)
    # tied placements may land on clusters with different power
    assert float(r_jax.task_energy_uj) == pytest.approx(
        r_ref["task_energy_uj"], rel=0.05)

"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting shapes and finiteness; and a
prefill+decode consistency check."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.train import optimizer as optim

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def _batch(cfg, seed=0):
    k = jax.random.PRNGKey(seed)
    shape = (B, cfg.n_codebooks, S) if cfg.n_codebooks > 1 else (B, S)
    toks = jax.random.randint(k, shape, 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            k, (B, cfg.n_prefix_embeds, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    params = lm.lm_init(KEY, cfg)
    batch = _batch(cfg)

    loss, metrics = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b))(
        params, batch)
    assert jnp.isfinite(loss), arch
    assert 0 < float(loss) < 3 * np.log(cfg.vocab)

    # one full train step: loss decreases after a few steps on same batch
    ocfg = optim.AdamWConfig(lr_peak=5e-3, warmup_steps=1, total_steps=10)
    opt_state = optim.adamw_init(params)

    @jax.jit
    def step(p, o, b):
        (l, _), g = jax.value_and_grad(
            lambda pp: lm.loss_fn(pp, cfg, b), has_aux=True)(p)
        p2, o2, _ = optim.adamw_update(ocfg, g, o, p)
        return p2, o2, l

    l0 = None
    for _ in range(5):
        params, opt_state, l = step(params, opt_state, batch)
        l0 = float(l) if l0 is None else l0
    assert jnp.isfinite(l), arch
    assert float(l) < l0, f"{arch}: loss did not decrease {l0}->{float(l)}"


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = configs.get_smoke_config(arch)
    if cfg.window:
        cfg = configs.scaled_down(configs.get_config(arch), window=8)
    if cfg.moe is not None:   # avoid capacity-drop divergence in the check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    params = lm.lm_init(KEY, cfg)
    batch = _batch(cfg, seed=1)
    toks = batch["tokens"]
    pe = batch.get("prefix_embeds")

    full_logits, _, _ = lm.forward(params, cfg, toks, prefix_embeds=pe)
    ref = (full_logits[:, -1] if cfg.n_codebooks == 1
           else full_logits[:, :, -1])

    npre = cfg.n_prefix_embeds
    caches = lm.init_caches(cfg, B, max_len=S + npre, dtype=jnp.float32)
    t_in = toks[..., :-1]
    t_last = toks[..., -1]
    _, caches = lm.prefill(params, cfg, t_in, caches, prefix_embeds=pe)
    pos = S - 1 + npre
    positions = jnp.full((B, 1), pos, jnp.int32) if npre else None
    logits, _ = lm.decode_step(params, cfg, t_last, pos, caches,
                               positions=positions)
    err = float(jnp.max(jnp.abs(logits.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    rel = err / (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 5e-2, f"{arch}: decode mismatch rel={rel}"


def test_full_configs_have_exact_assigned_dims():
    expect = {
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102400),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "mamba2-780m": (48, 1536, 48, 48, 0, 50280),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = configs.get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch


def test_moe_active_params_below_total():
    cfg = configs.get_smoke_config("deepseek-v2-lite-16b")
    params = lm.lm_init(KEY, cfg)
    total = lm.param_count(params)
    active = lm.active_param_count(cfg, params)
    assert active < total

"""Classifier zoo tests: DT/LR correctness, feature selection, DTree
lowering to the simulator's fixed arrays."""
import jax.numpy as jnp
import numpy as np

from hyp_compat import hypothesis, st
from repro.core import classifier as clf
from repro.core.simulator import DTree


def _toy(n=2000, seed=0):
    rng = np.random.RandomState(seed)
    x = rng.randn(n, 4).astype(np.float32)
    y = ((x[:, 0] > 0.3) & (x[:, 2] < 0.5)).astype(np.int32)
    return x, y


def test_dt_learns_axis_aligned_concept():
    x, y = _toy()
    t = clf.DecisionTree.fit(x, y, depth=2)
    assert t.accuracy(x, y) > 0.95


def test_dt_depth1_weaker_than_depth2():
    x, y = _toy()
    t1 = clf.DecisionTree.fit(x, y, depth=1)
    t2 = clf.DecisionTree.fit(x, y, depth=2)
    assert t2.accuracy(x, y) >= t1.accuracy(x, y) - 1e-9


def test_dt_storage_grows_with_depth():
    x, y = _toy(4000)
    t2 = clf.DecisionTree.fit(x, y, depth=2)
    t8 = clf.DecisionTree.fit(x, y, depth=8, class_weight=None)
    assert t8.storage_kb() >= t2.storage_kb()
    assert t2.n_nodes() <= 7


def test_depth2_array_lowering_matches_host_predict():
    x, y = _toy()
    t = clf.DecisionTree.fit(x, y, depth=2)
    arr = t.to_depth2_arrays()
    host = t.predict(x)
    dev = np.array([int(arr.predict(jnp.asarray(row))) for row in x[:200]])
    assert (dev == host[:200]).all()


def test_lr_learns_linear_concept():
    rng = np.random.RandomState(1)
    x = rng.randn(3000, 3).astype(np.float32)
    y = (x @ np.array([1.0, -2.0, 0.5]) > 0).astype(np.int32)
    m = clf.LogisticRegression.fit(x, y, steps=300)
    assert m.accuracy(x, y) > 0.93
    assert m.storage_kb() == (3 + 1) * 4 / 1024.0


def test_greedy_select_finds_informative_features():
    x, y = _toy()
    sel = clf.greedy_select(x, y, k=2)
    assert set(sel) == {0, 2}


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(seed=st.integers(0, 10_000))
def test_property_dt_predictions_binary(seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(200, 3).astype(np.float32)
    y = rng.randint(0, 2, 200).astype(np.int32)
    t = clf.DecisionTree.fit(x, y, depth=3)
    p = t.predict(x)
    assert set(np.unique(p)).issubset({0, 1})


def test_balanced_weighting_handles_skew():
    rng = np.random.RandomState(0)
    x = rng.randn(5000, 2).astype(np.float32)
    y = ((x[:, 0] > 1.5)).astype(np.int32)       # ~7% positives
    t = clf.DecisionTree.fit(x, y, depth=2)
    # recall of the minority class must be decent with balancing
    pred = t.predict(x)
    recall = (pred[y == 1] == 1).mean()
    assert recall > 0.8

"""Sharded + padded sweep engine (`sim.run_batch`).

Per-scenario results must be bit-exact vs the sequential `sim.run` path
for every mode, with and without a stacked `FaultPlan`, and invariant to
`batch_size`, device count, and final-chunk padding.

On a plain run this exercises the padded chunking path on however many
devices the process sees (usually one). CI re-runs this module under
`XLA_FLAGS=--xla_force_host_platform_device_count=4` in both jobs so the
real multi-device `shard_map` path is exercised on CPU-only runners.
"""
import jax
import numpy as np
import pytest

from repro.core import faults as flt, simulator as sim, workloads

PARAMS = sim.make_params()
SUITE = workloads.default_suite(n_instances=6)
# 5 scenarios: every chunk size below leaves a ragged, padded final chunk,
# and 5 never divides a forced 4-device shard evenly
CELLS = [(0, 0), (1, 7), (5, 13), (3, 5), (4, 9)]
WLS = [SUITE.build(mi, ri) for mi, ri in CELLS]
N_DEV = len(jax.devices())

ALL_MODES = [sim.MODE_LUT, sim.MODE_ETF, sim.MODE_ETF_IDEAL, sim.MODE_DAS,
             sim.MODE_ORACLE, sim.MODE_THRESHOLD]
SCALARS = ("avg_exec_us", "total_energy_uj", "edp", "n_decisions",
           "n_fast", "n_slow", "n_done", "task_energy_uj",
           "sched_energy_uj", "n_iters")
FAULT_SCALARS = ("n_faults", "n_retries", "reexec_us", "n_dropped_jobs",
                 "n_dropped_tasks", "recovery_us", "n_recovered")


def _mixed_tree() -> sim.DTree:
    import jax.numpy as jnp
    return sim.DTree(feat=jnp.array([sim.FEAT_RATE, 1, 1], jnp.int32),
                     thr=jnp.array([500.0, 4.0, 6.0], jnp.float32),
                     leaf=jnp.array([0, 1, 0, 1], jnp.int32))


def _assert_cell_equal(rs, rk, fields, ctx):
    for name in fields:
        a = np.asarray(getattr(rs, name))
        b = np.asarray(getattr(rk, name))
        assert np.array_equal(a, b), (ctx, name, a, b)
    np.testing.assert_array_equal(np.asarray(rs.finish),
                                  np.asarray(rk.finish), err_msg=str(ctx))
    np.testing.assert_array_equal(np.asarray(rs.pe_of),
                                  np.asarray(rk.pe_of), err_msg=str(ctx))


@pytest.mark.parametrize("mode", ALL_MODES)
def test_sharded_padded_matches_run(mode):
    """batch_size=2 over all devices: padded + (when multi-device)
    sharded chunks, bit-exact vs the per-scenario sequential path."""
    tree = _mixed_tree() if mode == sim.MODE_DAS else None
    rb = sim.run_batch(mode, WLS, PARAMS, tree=tree, rate_threshold=500.0,
                       batch_size=2, devices=N_DEV)
    for k, wl in enumerate(WLS):
        rs = sim.run(mode, wl, PARAMS, tree=tree, rate_threshold=500.0)
        _assert_cell_equal(rs, sim.result_at(rb, k), SCALARS, (mode, k))


def test_invariant_to_batch_size_devices_and_padding():
    """The same sweep through every chunking/sharding configuration —
    including sizes that force pad widths 0..B-1 — is one result."""
    tree = _mixed_tree()
    ref = sim.run_batch(sim.MODE_DAS, WLS, PARAMS, tree=tree, devices=1)
    for bs in (1, 2, 3, 5, None):
        for dev in sorted({1, N_DEV}):
            r = sim.run_batch(sim.MODE_DAS, WLS, PARAMS, tree=tree,
                              batch_size=bs, devices=dev)
            for name in SCALARS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(ref, name)),
                    np.asarray(getattr(r, name)),
                    err_msg=f"batch_size={bs} devices={dev} field={name}")
            np.testing.assert_array_equal(np.asarray(ref.finish),
                                          np.asarray(r.finish),
                                          err_msg=f"bs={bs} dev={dev}")


@pytest.mark.parametrize("mode", [sim.MODE_LUT, sim.MODE_DAS])
def test_stacked_fault_plans_sharded(mode):
    """A stacked per-scenario FaultPlan threads through the padded,
    sharded chunks bit-exactly (pad lanes replay the last plan, results
    sliced off)."""
    tree = _mixed_tree() if mode == sim.MODE_DAS else None
    plans = [flt.random_plan(s) for s in range(len(WLS))]
    rb = sim.run_batch(mode, WLS, PARAMS, tree=tree, rate_threshold=500.0,
                       plan=flt.stack_plans(plans), batch_size=2,
                       devices=N_DEV)
    for k, (wl, pl) in enumerate(zip(WLS, plans)):
        rs = sim.run(mode, wl, PARAMS, tree=tree, rate_threshold=500.0,
                     plan=pl)
        _assert_cell_equal(rs, sim.result_at(rb, k),
                           SCALARS + FAULT_SCALARS, (mode, k))


def test_shared_plan_sharded():
    """An unbatched (shared) plan is replicated across shards, not
    sliced; the healthy plan keeps the fault path bit-identical."""
    plan = flt.healthy_plan()
    rb = sim.run_batch(sim.MODE_ETF, WLS, PARAMS, plan=plan, batch_size=3,
                       devices=N_DEV)
    for k, wl in enumerate(WLS):
        rs = sim.run(sim.MODE_ETF, wl, PARAMS, plan=plan)
        _assert_cell_equal(rs, sim.result_at(rb, k),
                           SCALARS + FAULT_SCALARS, k)


@pytest.mark.parametrize("mode", ALL_MODES)
def test_stacked_fault_plans_kernels_on(mode):
    """Stacked per-scenario FaultPlans through the kernel-backed decision
    path (`kernels="xla"`), sharded + padded: bit-exact vs the inline
    sequential path for every mode."""
    tree = _mixed_tree() if mode == sim.MODE_DAS else None
    plans = [flt.random_plan(s) for s in range(len(WLS))]
    rb = sim.run_batch(mode, WLS, PARAMS, tree=tree, rate_threshold=500.0,
                       plan=flt.stack_plans(plans), batch_size=2,
                       devices=N_DEV, kernels="xla")
    for k, (wl, pl) in enumerate(zip(WLS, plans)):
        rs = sim.run(mode, wl, PARAMS, tree=tree, rate_threshold=500.0,
                     plan=pl, kernels="off")
        _assert_cell_equal(rs, sim.result_at(rb, k),
                           SCALARS + FAULT_SCALARS, (mode, k))


def test_dead_pe_degraded_etf_tie_breaks_kernels_on():
    """Kill whole clusters at t=0 so the degraded ETF search runs against
    a mostly-dead PE mask: the kernel path must pick the same first-
    global-minimum (slot, pe) as the inline path — the tie-break case the
    masked argmin is most likely to get wrong."""
    plan = flt.fail_cluster(flt.healthy_plan(), 0, at=0.0)
    plan = flt.fail_cluster(plan, 2, at=0.0)
    plan = flt.fail_pes(plan, [9, 10, 11], at=50.0)
    dead_from_t0 = np.where(np.asarray(plan.pe_fail_at) == 0.0)[0]
    for wl in WLS[:3]:
        r0 = sim.run(sim.MODE_ETF, wl, PARAMS, plan=plan, kernels="off")
        rx = sim.run(sim.MODE_ETF, wl, PARAMS, plan=plan, kernels="xla")
        rp = sim.run(sim.MODE_ETF, wl, PARAMS, plan=plan, kernels="pallas")
        # the alive mask constrained choices: never-alive PEs never chosen
        pe_of = np.asarray(r0.pe_of)
        assert not np.isin(pe_of[pe_of >= 0], dead_from_t0).any()
        assert int(r0.n_done) > 0
        for name in sim.SimResult._fields:
            a = np.asarray(getattr(r0, name))
            assert a.tobytes() == np.asarray(getattr(rx, name)).tobytes(), \
                ("xla", name)
            assert a.tobytes() == np.asarray(getattr(rp, name)).tobytes(), \
                ("pallas", name)


def test_multi_device_mesh_really_shards():
    """Under XLA_FLAGS=--xla_force_host_platform_device_count=N this is
    the test that proves the multi-device path ran (the others pass on one
    device too)."""
    if N_DEV < 2:
        pytest.skip("single-device process; CI runs this with 4 host "
                    "devices via XLA_FLAGS")
    ref = sim.run_batch(sim.MODE_LUT, WLS, PARAMS, devices=1)
    shd = sim.run_batch(sim.MODE_LUT, WLS, PARAMS, batch_size=len(WLS),
                        devices=N_DEV)
    np.testing.assert_array_equal(np.asarray(ref.avg_exec_us),
                                  np.asarray(shd.avg_exec_us))
    np.testing.assert_array_equal(np.asarray(ref.finish),
                                  np.asarray(shd.finish))


def test_devices_knob_validation():
    with pytest.raises(ValueError, match="out of range"):
        sim.run_batch(sim.MODE_LUT, WLS, PARAMS, devices=N_DEV + 1)
    with pytest.raises(ValueError, match="not an integer"):
        import os
        os.environ["REPRO_BENCH_DEVICES"] = "lots"
        try:
            sim._resolve_devices(None)
        finally:
            del os.environ["REPRO_BENCH_DEVICES"]


def test_devices_env_knob(monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_DEVICES", "1")
    r = sim.run_batch(sim.MODE_LUT, WLS, PARAMS, batch_size=2)
    ref = sim.run_batch(sim.MODE_LUT, WLS, PARAMS, devices=1)
    np.testing.assert_array_equal(np.asarray(ref.avg_exec_us),
                                  np.asarray(r.avg_exec_us))

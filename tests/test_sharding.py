"""Sharding rules + dry-run machinery tests (CPU: 1-device mesh semantics,
plus pure-python checks of the spec rules against the production mesh
geometry via abstract arrays)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import hlo_analysis
from repro.models import lm
from repro.parallel import sharding
from repro.train import optimizer as optim


class FakeMesh:
    """Geometry-only stand-in for the 16x16 production mesh (no devices)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _abs_params(arch):
    cfg = configs.get_config(arch)
    return cfg, jax.eval_shape(lambda k: lm.lm_init(k, cfg),
                               jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_param_specs_divisible(arch):
    """Every parameter's sharding must divide its dims on the production
    mesh — the exact precondition jit enforces."""
    cfg, params = _abs_params(arch)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        spec = sharding.param_spec(path, leaf, cfg, MESH)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            size = sharding._axis_size(MESH, ax)
            assert dim % size == 0, (arch, path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["yi-34b", "deepseek-v2-lite-16b",
                                  "mamba2-780m", "recurrentgemma-9b"])
def test_cache_specs_divisible(arch):
    cfg = configs.get_config(arch)
    caches = jax.eval_shape(lambda: lm.init_caches(cfg, 128, 1024))
    flat = jax.tree_util.tree_flatten_with_path(caches)[0]
    for path, leaf in flat:
        spec = sharding.cache_spec(path, leaf, cfg, MESH3)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            assert dim % sharding._axis_size(MESH3, ax) == 0, (arch, path)


def test_moe_experts_sharded_on_model():
    cfg, params = _abs_params("dbrx-132b")
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    found = 0
    for path, leaf in flat:
        keys = sharding._path_keys(path)
        if ("mlp" in keys and keys[-1] in ("w_gate", "w_up", "w_down")
                and leaf.ndim >= 3 and 16 in leaf.shape):
            spec = tuple(sharding.param_spec(path, leaf, cfg, MESH))
            assert "model" in spec, (path, spec)
            found += 1
    assert found >= 3


def test_batch_spec_small_batch_replicated():
    assert tuple(sharding.batch_spec(MESH3, 1, (1,))) == (None,)
    sp = sharding.batch_spec(MESH3, 2, (128, 5))
    assert sp[0] == ("pod", "data")


def test_vocab_padding():
    cfg = configs.get_config("minicpm3-4b")
    assert cfg.vocab_padded % 16 == 0
    assert cfg.vocab_padded >= cfg.vocab
    cfg2 = configs.get_config("yi-34b")
    assert cfg2.vocab_padded == cfg2.vocab


# ---------------------------------------------------------------------------
# hlo_analysis unit tests
# ---------------------------------------------------------------------------
HLO_SNIPPET = """
HloModule test

%cond.1 (arg.1: (s32[], f32[8,8])) -> pred[] {
  %c = s32[] constant(10)
  %p = s32[] parameter(0)
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

%body.1 (arg.2: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %w = f32[8,16]{1,0} parameter(1)
  %x = f32[16,8]{1,0} parameter(2)
  %dot.5 = f32[8,8]{1,0} dot(%w, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,8]{1,0} all-gather(%dot.5), dimensions={0}
}

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %init = f32[8,8]{1,0} parameter(0)
  %wh = (s32[], f32[8,8]) while(%t), condition=%cond.1, body=%body.1
  %ar = f32[8,8]{1,0} all-reduce(%gte2), to_apply=%add
}
"""


def test_hlo_trip_weighted_analysis():
    res = hlo_analysis.analyze(HLO_SNIPPET)
    # dot: 2*8*8*16 = 2048 flops, x10 trips = 20480
    assert res["dot_flops"] == 20480
    cb = res["collective_bytes"]
    # all-gather inside the loop: 8*8*4 bytes x 10; all-reduce outside: x1
    assert cb["all-gather"] == 8 * 8 * 4 * 10
    assert cb["all-reduce"] == 8 * 8 * 4
    assert res["n_while"] == 1


def test_hlo_symbols_resolution():
    syms = hlo_analysis.build_symbols(HLO_SNIPPET)
    assert syms["dot.5"] == ("f32", "8,8")
    assert syms["w"] == ("f32", "8,16")


def test_activation_policy_constrain_noop_without_policy():
    x = jnp.ones((4, 8))
    y = sharding.constrain(x, ("batch", None))
    assert y.shape == x.shape

import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)

# NOTE: do NOT set XLA_FLAGS/device-count here — smoke tests and benches
# must see the real single CPU device; only launch/dryrun.py forces 512.

"""Roofline + dry-run artifact tests (consume dryrun_results.json when
present; pure-unit otherwise)."""
import json
import os

import pytest

from repro import configs
from repro.launch import roofline
from repro.launch.shapes import SHAPES, applicable, cells

RESULTS = "/root/repo/dryrun_results.json"


def test_cell_enumeration_and_skips():
    cfgs = {a: configs.get_config(a) for a in configs.ARCH_IDS}
    cs = cells(cfgs)
    # 10 archs x 4 shapes = 40; 8 full-attention archs skip long_500k
    assert len(cs) == 40 - 8
    for a in ("mamba2-780m", "recurrentgemma-9b"):
        assert (a, "long_500k") in cs
    for a in ("yi-34b", "qwen2-72b", "dbrx-132b"):
        assert (a, "long_500k") not in cs
        assert applicable(cfgs[a], "long_500k") is not None


def test_roofline_terms_math():
    cell = {
        "status": "ok", "n_devices": 256,
        "dot_flops_per_dev": 197e12,       # exactly 1s of compute
        "dot_bytes_per_dev": 819e9 / 2,    # 0.5s of memory
        "collective_bytes": {"all-gather": 50e9 / 4},
        "model_flops_global": 197e12 * 256 / 2,
    }
    t = roofline.roofline_terms(cell)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.5)
    assert t["collective_s"] == pytest.approx(0.25)
    assert t["dominant"] == "compute_s"
    assert t["roofline_fraction"] == pytest.approx(0.5)
    assert t["useful_ratio"] == pytest.approx(0.5)


def test_tpu_corrected_bytes_preferred():
    cell = {
        "status": "ok", "n_devices": 256,
        "dot_flops_per_dev": 1e12, "dot_bytes_per_dev": 1e9,
        "collective_bytes": {"all-reduce": 100e9},
        "collective_bytes_tpu": {"all-reduce": 50e9},
        "model_flops_global": 1e12 * 256,
    }
    t = roofline.roofline_terms(cell)
    assert t["collective_s"] == pytest.approx(1.0)   # uses the 50GB number


@pytest.mark.skipif(not os.path.exists(RESULTS),
                    reason="dry-run artifact not present")
def test_dryrun_artifact_complete_and_clean():
    with open(RESULTS) as f:
        results = json.load(f)
    assert len(results) == 80                      # 40 cells x 2 meshes
    assert sum(r["status"] == "failed" for r in results) == 0
    assert sum(r["status"] == "skipped" for r in results) == 16
    ok = [r for r in results if r["status"] == "ok"]
    assert len(ok) == 64
    rows = roofline.build_table(results)
    for r in rows:
        if r.get("status") == "ok":
            assert r["step_time_bound_s"] > 0
            assert 0 <= r["roofline_fraction"] <= 1.5

"""Batched simulator path: stack_workloads / simulate_batch / run_batch.

Covers the PR-6 tentpole: per-scenario results of the vmapped sweep must
match the sequential `sim.run` path (bit-for-bit on CPU), oracle generation
must be identical through either path, chunking must not change results,
and the deadlock guard must terminate instead of spinning to `max_iters`.
"""
import numpy as np
import pytest

from repro.core import oracle, simulator as sim, workloads

PARAMS = sim.make_params()
SUITE = workloads.default_suite(n_instances=8)
CELLS = [(0, 0), (0, 13), (5, 0), (5, 13)]
WLS = [SUITE.build(mi, ri) for mi, ri in CELLS]

ALL_MODES = [sim.MODE_LUT, sim.MODE_ETF, sim.MODE_ETF_IDEAL, sim.MODE_DAS,
             sim.MODE_ORACLE, sim.MODE_THRESHOLD]

SCALARS = ("avg_exec_us", "total_energy_uj", "edp", "n_decisions",
           "n_fast", "n_slow", "n_done", "task_energy_uj",
           "sched_energy_uj")


def _mixed_tree() -> sim.DTree:
    """A depth-2 tree that actually splits on rate (some F, some S)."""
    import jax.numpy as jnp
    return sim.DTree(feat=jnp.array([sim.FEAT_RATE, 1, 1], jnp.int32),
                     thr=jnp.array([500.0, 4.0, 6.0], jnp.float32),
                     leaf=jnp.array([0, 1, 0, 1], jnp.int32))


# ---------------------------------------------------------------------------
# stack_workloads
# ---------------------------------------------------------------------------
def test_stack_workloads_shapes_and_values():
    stacked = workloads.stack_workloads(WLS)
    for name, field in zip(workloads.FlatWorkload._fields, stacked):
        assert field.shape[0] == len(WLS), name
        for k, wl in enumerate(WLS):
            np.testing.assert_array_equal(field[k], getattr(wl, name))


def test_stack_workloads_rejects_shape_mismatch():
    other = workloads.default_suite(n_instances=4).build(0, 0)
    with pytest.raises(ValueError, match="shape mismatch"):
        workloads.stack_workloads([WLS[0], other])


def test_build_many_matches_build():
    stacked = SUITE.build_many(CELLS)
    for k, wl in enumerate(WLS):
        np.testing.assert_array_equal(stacked.task_type[k], wl.task_type)
        np.testing.assert_array_equal(stacked.inst_arrival[k],
                                      wl.inst_arrival)


# ---------------------------------------------------------------------------
# batched vs sequential equivalence (all six modes)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ALL_MODES)
def test_run_batch_matches_sequential(mode):
    tree = _mixed_tree() if mode == sim.MODE_DAS else None
    thr = 500.0
    rb = sim.run_batch(mode, WLS, PARAMS, tree=tree, rate_threshold=thr)
    for k, wl in enumerate(WLS):
        rs = sim.run(mode, wl, PARAMS, tree=tree, rate_threshold=thr)
        rk = sim.result_at(rb, k)
        for name in SCALARS:
            a = np.asarray(getattr(rs, name))
            b = np.asarray(getattr(rk, name))
            assert np.array_equal(a, b), (name, a, b)
        np.testing.assert_array_equal(np.asarray(rs.log_feat),
                                      np.asarray(rk.log_feat))
        np.testing.assert_array_equal(np.asarray(rs.finish),
                                      np.asarray(rk.finish))
        np.testing.assert_array_equal(np.asarray(rs.pe_of),
                                      np.asarray(rk.pe_of))


def test_run_batch_chunking_is_invariant():
    full = sim.run_batch(sim.MODE_LUT, WLS, PARAMS)
    # batch sizes that exercise no-pad, ragged-pad, and per-scenario
    # chunking; devices=1 pins the sharding knob for determinism
    for bs in (1, 2, 3):
        chunked = sim.run_batch(sim.MODE_LUT, WLS, PARAMS, batch_size=bs,
                                devices=1)
        for name in SCALARS:
            np.testing.assert_array_equal(np.asarray(getattr(full, name)),
                                          np.asarray(getattr(chunked, name)),
                                          err_msg=f"batch_size={bs} {name}")


def test_ragged_final_chunk_does_not_retrace():
    """n=8 with batch_size=5 pads the final chunk [3] -> [5]: the whole
    sweep must reuse ONE compiled executable (the pre-padding engine
    traced a second program for the remainder shape), and the padded
    results must match the unchunked sweep."""
    wls = WLS + WLS
    before = sim.TRACE_COUNT["simulate_batch"]
    chunked = sim.run_batch(sim.MODE_LUT, wls, PARAMS, batch_size=5,
                            devices=1)
    assert sim.TRACE_COUNT["simulate_batch"] - before <= 1
    full = sim.run_batch(sim.MODE_LUT, wls, PARAMS)
    for name in SCALARS:
        np.testing.assert_array_equal(np.asarray(getattr(full, name)),
                                      np.asarray(getattr(chunked, name)),
                                      err_msg=name)
    np.testing.assert_array_equal(np.asarray(full.finish),
                                  np.asarray(chunked.finish))


def test_run_batch_per_scenario_threshold():
    """`rate_threshold` with a leading [S] axis sweeps per scenario."""
    import jax.numpy as jnp
    wls = [WLS[1], WLS[1]]  # same high-rate scenario twice
    # never-slow vs always-slow (rate_est is 0 before two arrivals, so the
    # always-slow threshold must be <= 0)
    thr = jnp.array([1e9, 0.0], jnp.float32)
    r = sim.run_batch(sim.MODE_THRESHOLD, wls, PARAMS, rate_threshold=thr)
    assert int(r.n_slow[0]) == 0
    assert int(r.n_slow[1]) == int(r.n_decisions[1])


def test_run_batch_per_scenario_trees():
    """`tree` with a leading [S] axis selects a tree per scenario."""
    import jax
    fast = sim.always_fast_tree()
    slow = fast._replace(leaf=fast.leaf + 1)  # all leaves -> S
    trees = jax.tree_util.tree_map(lambda a, b: np.stack([a, b]), fast, slow)
    wls = [WLS[2], WLS[2]]
    r = sim.run_batch(sim.MODE_DAS, wls, PARAMS, tree=sim.DTree(
        *[np.asarray(x) for x in trees]))
    assert int(r.n_slow[0]) == 0
    assert int(r.n_slow[1]) == int(r.n_decisions[1])


# ---------------------------------------------------------------------------
# kernel-backed decision path (PR-10): REPRO_SIM_KERNELS on, bit-exact
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ALL_MODES)
def test_run_batch_kernels_xla_matches_sequential(mode):
    """The fused-XLA decision path (`kernels="xla"`) must be bit-exact vs
    the inline-jnp sequential path for every mode — same first-global-min
    argmin tie-break, same push-time contribution max."""
    tree = _mixed_tree() if mode == sim.MODE_DAS else None
    rb = sim.run_batch(mode, WLS, PARAMS, tree=tree, rate_threshold=500.0,
                       kernels="xla")
    for k, wl in enumerate(WLS):
        rs = sim.run(mode, wl, PARAMS, tree=tree, rate_threshold=500.0,
                     kernels="off")
        rk = sim.result_at(rb, k)
        for name in SCALARS:
            assert np.array_equal(np.asarray(getattr(rs, name)),
                                  np.asarray(getattr(rk, name))), \
                (mode, k, name)
        np.testing.assert_array_equal(np.asarray(rs.finish),
                                      np.asarray(rk.finish))
        np.testing.assert_array_equal(np.asarray(rs.pe_of),
                                      np.asarray(rk.pe_of))


@pytest.mark.parametrize("mode", [sim.MODE_ETF, sim.MODE_DAS])
def test_run_kernels_pallas_interpret_matches(mode):
    """The Pallas kernels (interpret mode off-TPU — the TPU kernel's
    semantics) agree bit-exactly with the inline path. Sequential runs
    only: interpret mode pays a Python visit per grid step."""
    tree = _mixed_tree() if mode == sim.MODE_DAS else None
    wl = WLS[1]
    r0 = sim.run(mode, wl, PARAMS, tree=tree, rate_threshold=500.0,
                 kernels="off")
    rp = sim.run(mode, wl, PARAMS, tree=tree, rate_threshold=500.0,
                 kernels="pallas")  # off-TPU -> pallas-interpret
    for name in sim.SimResult._fields:
        a, b = np.asarray(getattr(r0, name)), np.asarray(getattr(rp, name))
        assert a.tobytes() == b.tobytes(), (mode, name, a, b)


def test_run_batch_kernels_telemetry():
    """`telemetry=[]` collects one record per dispatch: allocated vs
    active lane-trips, retired events, and an occupancy in (0, 1]."""
    tel = []
    r = sim.run_batch(sim.MODE_ETF, WLS, PARAMS, batch_size=2, devices=1,
                      kernels="xla", telemetry=tel)
    assert len(tel) == 2  # ceil(4/2) chunks
    assert sum(t["events"] for t in tel) == int(np.asarray(r.n_iters).sum())
    for t in tel:
        assert t["lanes"] == 2
        assert 0 < t["active_trips"] <= t["lane_trips"]
        assert 0 < t["occupancy"] <= 1.0


def test_kernels_no_retrace_across_two_sweeps():
    """With kernels on, a second same-shape sweep must add ZERO retraces
    — the dispatch mode is a static jit arg, so flipping nothing reuses
    the warm executable."""
    cells_b = [(1, 1), (2, 3), (3, 5), (4, 7)]
    wls_b = [SUITE.build(mi, ri) for mi, ri in cells_b]
    sim.run_batch(sim.MODE_ETF, WLS, PARAMS, batch_size=2, devices=1,
                  kernels="xla")  # warm
    before = dict(sim.TRACE_COUNT)
    sim.run_batch(sim.MODE_ETF, wls_b, PARAMS, batch_size=2, devices=1,
                  kernels="xla")
    assert sim.TRACE_COUNT == before, (before, sim.TRACE_COUNT)


# ---------------------------------------------------------------------------
# oracle: batched == sequential, bit for bit
# ---------------------------------------------------------------------------
def test_oracle_generate_batched_equals_sequential():
    kw = dict(mix_indices=[0, 5], rate_indices=[0, 7], metric="avg_exec_us")
    ds_b = oracle.generate(SUITE, PARAMS, batched=True, batch_size=3, **kw)
    ds_s = oracle.generate(SUITE, PARAMS, batched=False, **kw)
    np.testing.assert_array_equal(ds_b.features, ds_s.features)
    np.testing.assert_array_equal(ds_b.labels, ds_s.labels)
    np.testing.assert_array_equal(ds_b.groups, ds_s.groups)
    np.testing.assert_array_equal(ds_b.rates, ds_s.rates)


# ---------------------------------------------------------------------------
# deadlock guard (PR-6 bugfix): stalls terminate, they don't spin
# ---------------------------------------------------------------------------
def _unschedulable(wl: workloads.FlatWorkload) -> workloads.FlatWorkload:
    """Instance 0 arrives but its roots are never released: its tasks can
    never become ready, so the run can't complete."""
    n_roots = np.array(wl.inst_n_roots)
    n_roots[0] = 0
    return wl._replace(inst_n_roots=n_roots)


def test_unschedulable_workload_stalls_early():
    wl = _unschedulable(WLS[0])
    r = sim.run(sim.MODE_LUT, wl, PARAMS)
    T = wl.task_type.shape[0]
    I = wl.inst_arrival.shape[0]
    max_iters = 3 * T + I + 64
    assert bool(r.stalled)
    assert int(r.n_done) < int(wl.n_tasks)
    # the old guard set now=now and spun until max_iters
    assert int(r.n_iters) < max_iters - 32
    # decision+completion per done task, arrivals, and <= one advance
    # between consecutive events
    assert int(r.n_iters) <= 3 * int(r.n_done) + 2 * I + 16


def test_healthy_workload_does_not_stall():
    r = sim.run(sim.MODE_LUT, WLS[0], PARAMS)
    assert not bool(r.stalled)
    assert int(r.n_done) == int(WLS[0].n_tasks)

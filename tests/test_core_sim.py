"""DAS core simulator: unit + property tests (hypothesis optional)."""
import jax.numpy as jnp
import numpy as np
import pytest

from hyp_compat import hypothesis, st
from repro.core import dfg, oracle, simulator as sim, soc, workloads

PARAMS = sim.make_params()
SUITE = workloads.default_suite(n_instances=12)


def _run(mode, mix=5, rate=5, **kw):
    wl = SUITE.build(mix, rate)
    return wl, sim.run(mode, wl, PARAMS, **kw)


# ---------------------------------------------------------------------------
# basic invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", [sim.MODE_LUT, sim.MODE_ETF,
                                  sim.MODE_ETF_IDEAL, sim.MODE_ORACLE])
def test_all_tasks_complete(mode):
    wl, r = _run(mode)
    assert int(r.n_done) == int(wl.n_tasks)
    assert int(r.ready_drop) == 0
    assert np.isfinite(float(r.avg_exec_us))
    assert float(r.avg_exec_us) > 0


def test_one_decision_per_task():
    wl, r = _run(sim.MODE_LUT)
    assert int(r.n_decisions) == int(wl.n_tasks)
    # every task got a PE and a finite finish time
    valid = np.asarray(wl.task_valid)
    assert (np.asarray(r.pe_of)[valid] >= 0).all()
    assert np.isfinite(np.asarray(r.finish)[valid]).all()


def test_precedence_respected():
    """No task starts before all its predecessors finish (comm >= 0)."""
    wl, r = _run(sim.MODE_ETF)
    finish = np.asarray(r.finish)
    # start = finish - exec
    exec_pe = np.asarray(PARAMS.exec_pe)
    starts = finish - exec_pe[np.asarray(wl.task_type),
                              np.clip(np.asarray(r.pe_of), 0, None)]
    for t in range(int(wl.n_tasks)):
        for k in range(int(wl.n_preds[t])):
            p = int(wl.preds[t, k])
            assert starts[t] >= finish[p] - 1e-3, (t, p)


def test_pe_no_overlap():
    """A PE runs at most one task at a time."""
    wl, r = _run(sim.MODE_LUT)
    finish = np.asarray(r.finish)
    pe_of = np.asarray(r.pe_of)
    exec_pe = np.asarray(PARAMS.exec_pe)
    starts = finish - exec_pe[np.asarray(wl.task_type),
                              np.clip(pe_of, 0, None)]
    for p in range(soc.N_PES):
        idx = np.where((pe_of == p) & np.asarray(wl.task_valid))[0]
        iv = sorted(zip(starts[idx], finish[idx]))
        for (s1, f1), (s2, f2) in zip(iv, iv[1:]):
            assert s2 >= f1 - 1e-3


def test_lut_uses_energy_efficient_cluster():
    wl, r = _run(sim.MODE_LUT)
    pe_cl = np.asarray(PARAMS.pe_cluster)
    lut = np.asarray(PARAMS.lut_cluster)
    valid = np.asarray(wl.task_valid)
    got = pe_cl[np.clip(np.asarray(r.pe_of), 0, None)]
    want = lut[np.asarray(wl.task_type)]
    assert (got[valid] == want[valid]).all()


def test_etf_ideal_not_worse_than_etf():
    _, r1 = _run(sim.MODE_ETF)
    _, r2 = _run(sim.MODE_ETF_IDEAL)
    assert float(r2.avg_exec_us) <= float(r1.avg_exec_us) + 1e-3


def test_sched_energy_ordering():
    """LUT scheduling energy < ETF scheduling energy (same workload)."""
    _, rl = _run(sim.MODE_LUT)
    _, re_ = _run(sim.MODE_ETF)
    assert float(rl.sched_energy_uj) < float(re_.sched_energy_uj)


def test_das_mode_runs_and_mixes():
    from repro.core import das as das_mod
    ds = oracle.generate(SUITE, PARAMS, mix_indices=[0, 1, 5],
                         rate_indices=[0, 7, 13])
    pol = das_mod.fit_policy(ds)
    wl, r = _run(sim.MODE_DAS, tree=pol.tree)
    assert int(r.n_done) == int(wl.n_tasks)
    assert int(r.n_fast) + int(r.n_slow) == int(r.n_decisions)


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------
@hypothesis.settings(max_examples=8, deadline=None)
@hypothesis.given(
    mix=st.integers(0, 39),
    rate=st.integers(0, 13),
)
def test_property_completion_and_conservation(mix, rate):
    wl = SUITE.build(mix, rate)
    r = sim.run(sim.MODE_LUT, wl, PARAMS)
    assert int(r.n_done) == int(wl.n_tasks)
    assert int(r.n_decisions) == int(wl.n_tasks)
    # energy equals sum of task energies + scheduling energy
    assert float(r.total_energy_uj) == pytest.approx(
        float(r.task_energy_uj) + float(r.sched_energy_uj), rel=1e-5)
    # makespan bounds every instance latency
    lat = np.asarray(r.inst_exec_us)
    lat = lat[np.isfinite(lat)]
    assert (lat >= 0).all()


@hypothesis.settings(max_examples=6, deadline=None)
@hypothesis.given(rate=st.integers(0, 13))
def test_property_oracle_labels_well_formed(rate):
    wl = SUITE.build(5, rate)
    feats, labels, info = oracle.label_one_run(wl, PARAMS)
    assert feats.shape[0] == labels.shape[0] == info["n_decisions"]
    assert set(np.unique(labels)).issubset({0, 1})
    assert feats.shape[1] == sim.N_FEATURES
    assert np.isfinite(feats).all()


def test_dfg_graphs_are_dags():
    for name, g in dfg.APPS.items():
        d = g.depths()
        assert (d >= 0).all(), name
        for i, preds in enumerate(g.preds):
            for p in preds:
                assert p < i, name

"""LM details: chunked CE equivalence, banded local attention, vocab
padding masks, head modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import attention as A
from repro.models import lm

KEY = jax.random.PRNGKey(0)


def test_chunked_ce_matches_unchunked():
    cfg = configs.get_smoke_config("yi-34b", d_model=64, vocab=128)
    p = lm.lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 64), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    l1, _ = lm.loss_fn(p, cfg, batch, loss_chunk=16)
    l2, _ = lm.loss_fn(p, cfg, batch, loss_chunk=0)
    assert float(jnp.abs(l1 - l2)) < 1e-3


def test_banded_equals_masked_local_attention():
    B, S, H, K, D, W = 2, 96, 4, 2, 16, 32
    q = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, K, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    bias = A._mask_bias(pos, pos, W, None)
    ref = A.sdpa(q, k, v, bias)
    out = A.banded_sdpa(q, k, v, pos, W)
    assert float(jnp.max(jnp.abs(ref - out))) < 1e-5


def test_window_ring_cache_matches_full_cache():
    """Decode with the ring buffer == decode with a full-length cache."""
    import dataclasses
    cfg = configs.scaled_down(configs.get_config("recurrentgemma-9b"),
                              window=8)
    p = lm.lm_init(KEY, cfg)
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S + 4), 0,
                              cfg.vocab)
    # full forward reference for the final logits
    full_logits, _, _ = lm.forward(p, cfg, toks)
    # ring-cache decode of the last 4 tokens
    caches = lm.init_caches(cfg, B, max_len=S + 4, dtype=jnp.float32)
    _, caches = lm.prefill(p, cfg, toks[:, :S], caches)
    logits = None
    for i in range(4):
        logits, caches = lm.decode_step(p, cfg, toks[:, S + i], S + i,
                                        caches)
    rel = (float(jnp.max(jnp.abs(logits - full_logits[:, -1])))
           / (float(jnp.max(jnp.abs(full_logits[:, -1]))) + 1e-9))
    assert rel < 5e-2, rel


def test_vocab_padding_masked_in_head():
    cfg = configs.get_smoke_config("mamba2-780m", vocab=100)  # pads to 112
    assert cfg.vocab_padded == 112
    p = lm.lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab)
    logits, _, _ = lm.forward(p, cfg, toks)
    assert logits.shape[-1] == 112
    assert float(logits[..., 100:].max()) < -1e8


def test_head_mode_last_matches_full():
    cfg = configs.get_smoke_config("phi3-mini-3.8b", d_model=64, vocab=128)
    p = lm.lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    all_logits, _, _ = lm.forward(p, cfg, toks, head_mode="all")
    last_logits, _, _ = lm.forward(p, cfg, toks, head_mode="last")
    np.testing.assert_allclose(np.asarray(last_logits[:, 0]),
                               np.asarray(all_logits[:, -1]), rtol=1e-5)


def test_mla_absorbed_decode_matches_expanded():
    import dataclasses
    cfg = configs.get_smoke_config("minicpm3-4b")
    cfga = dataclasses.replace(cfg, mla_absorb=True)
    p = lm.lm_init(jax.random.PRNGKey(7), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(8), (B, S + 1), 0,
                              cfg.vocab)
    out = {}
    for name, c in [("exp", cfg), ("abs", cfga)]:
        caches = lm.init_caches(c, B, max_len=S + 1, dtype=jnp.float32)
        _, caches = lm.prefill(p, c, toks[:, :S], caches)
        logits, _ = lm.decode_step(p, c, toks[:, S], S, caches)
        out[name] = logits
    rel = (float(jnp.max(jnp.abs(out["exp"] - out["abs"])))
           / (float(jnp.max(jnp.abs(out["exp"]))) + 1e-9))
    assert rel < 2e-2, rel


def test_moe_sharded_dispatch_matches_global():
    import dataclasses
    cfg0 = configs.get_smoke_config("dbrx-132b")
    hi_cap = dataclasses.replace(cfg0.moe, capacity_factor=4.0)
    cfg1 = dataclasses.replace(cfg0, moe=hi_cap)
    cfg4 = dataclasses.replace(
        cfg0, moe=dataclasses.replace(hi_cap, n_dispatch_shards=4))
    p = lm.lm_init(KEY, cfg1)
    toks = jax.random.randint(KEY, (4, 32), 0, cfg1.vocab)
    l1, _ = lm.loss_fn(p, cfg1, {"tokens": toks, "labels": toks})
    l4, _ = lm.loss_fn(p, cfg4, {"tokens": toks, "labels": toks})
    assert float(jnp.abs(l1 - l4)) < 2e-2


def test_bf16_master_training_step():
    """bf16 weights + fp32 masters: loss decreases, params stay bf16."""
    from repro.train import optimizer as optim
    cfg = configs.get_smoke_config("phi3-mini-3.8b", n_layers=2,
                                   d_model=64, vocab=128)
    params = jax.tree.map(lambda p: p.astype(jnp.bfloat16),
                          lm.lm_init(KEY, cfg))
    state = optim.adamw_init(params, keep_master=True)
    ocfg = optim.AdamWConfig(lr_peak=5e-3, warmup_steps=1, total_steps=20)
    toks = jax.random.randint(KEY, (4, 64), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}

    @jax.jit
    def step(p, s):
        (l, _), g = jax.value_and_grad(
            lambda pp: lm.loss_fn(pp, cfg, batch), has_aux=True)(p)
        p2, s2, _ = optim.adamw_update(ocfg, g, s, p)
        return p2, s2, l

    l0 = None
    for _ in range(8):
        params, state, l = step(params, state)
        l0 = float(l) if l0 is None else l0
    assert float(l) < l0
    assert all(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(params)
               if x.dtype != jnp.int32)
    assert all(x.dtype == jnp.float32
               for x in jax.tree.leaves(state.master))

"""Serving engine + DAS dispatch tests."""
import numpy as np
import pytest

from repro import configs
from repro.serve import costmodel as cm
from repro.serve import dispatch as dsp
from repro.serve import engine as eng

CFG = eng.EngineConfig(n_replicas=3, max_batch=8)
SPEC = cm.ReplicaSpec("t", n_chips=4)
MC = cm.ModelCost.from_config(configs.get_config("phi3-mini-3.8b"))


def _run(dispatcher, rate=20.0, n=60, seed=0):
    reqs = eng.poisson_requests(rate, n, seed)
    return eng.run_engine(reqs, dispatcher, CFG, SPEC, MC)


def test_all_requests_complete():
    res = _run(dsp.LUTDispatcher(3))
    assert all(r.done_s >= 0 for r in res.requests)
    assert all(r.first_token_s >= r.arrival_s for r in res.requests
               if r.first_token_s >= 0)
    assert res.makespan_s > 0 and np.isfinite(res.energy_j)


def test_request_ordering_invariants():
    res = _run(dsp.ETFDispatcher(), rate=50, n=40)
    for r in res.requests:
        assert r.dispatched_s >= r.arrival_s
        assert r.done_s >= r.first_token_s >= r.dispatched_s
        assert r.tokens_out >= r.gen_len


def test_etf_balances_better_than_lut_under_skew():
    """With heavy load, ETF's finish-time search should not be much worse
    than the static table (usually better)."""
    r_lut = _run(dsp.LUTDispatcher(3), rate=100, n=100)
    r_etf = _run(dsp.ETFDispatcher(), rate=100, n=100)
    assert r_etf.mean_latency_s < r_lut.mean_latency_s * 1.5


def test_dispatch_latency_accounting():
    r = _run(dsp.ETFDispatcher(), rate=30, n=50)
    assert r.dispatch_busy_s > 0
    r2 = _run(dsp.LUTDispatcher(3), rate=30, n=50)
    assert r2.dispatch_busy_s < r.dispatch_busy_s


def test_das_dispatcher_trains_and_runs():
    scen = [(5, 40, 0), (80, 40, 0)]
    das = dsp.train_das_dispatcher(scen, CFG, SPEC, MC)
    assert 0.0 <= das.label_slow_frac <= 1.0
    res = _run(das, rate=40, n=60)
    assert res.dispatch_fast + res.dispatch_slow == 60


def test_cost_model_monotonicity():
    assert cm.prefill_seconds(MC, SPEC, 2048) > cm.prefill_seconds(
        MC, SPEC, 512)
    assert cm.decode_step_seconds(MC, SPEC, 16, 4096) >= \
        cm.decode_step_seconds(MC, SPEC, 1, 4096)
    # MLA cache smaller than GQA cache per token
    mla = cm.ModelCost.from_config(configs.get_config("minicpm3-4b"))
    gqa = cm.ModelCost.from_config(configs.get_config("yi-34b"))
    assert mla.kv_bytes_per_token < gqa.kv_bytes_per_token
    # SSM has no per-token cache growth
    ssm = cm.ModelCost.from_config(configs.get_config("mamba2-780m"))
    assert ssm.kv_bytes_per_token == 0.0

"""The 40-workload summary (paper IV-C): DAS speedup and EDP reduction vs
ETF at low data rates and vs LUT at high workload complexity; plus the
fraction of (workload, rate) cells where DAS >= min(LUT, ETF)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import workloads

LOW_RATES = [0, 1, 2]
HIGH_RATES = [11, 12, 13]
N_MIXES = 40 if common.FULL else 14


def run(csv=False):
    t0 = time.perf_counter()
    mixes = list(range(N_MIXES))
    # the paper labels pendings by "the target metric, such as the average
    # execution time OR energy-delay product": exec-trained policy for the
    # speedup claims, EDP-trained policy for the EDP claims.
    from repro.core import simulator as sim
    pol_edp = common.das_policy_auto("edp")
    sp_vs_etf, edp_vs_etf = [], []
    sp_vs_lut, edp_vs_lut = [], []
    das_best = 0
    cells = 0
    grid_cells = [(mi, ri) for mi in mixes for ri in LOW_RATES + HIGH_RATES]
    # one batched sweep per mode over the whole (mix x rate) grid
    grid = common.eval_modes_grid(grid_cells, with_fs=True)
    de_grid = common.eval_grid(grid_cells, sim.MODE_DAS, tree=pol_edp.tree)
    for k, (mi, ri) in enumerate(grid_cells):
        d = grid["DAS-FS"][k]
        l = grid["LUT"][k]
        e = grid["ETF"][k]
        de = de_grid[k]
        cells += 1
        if float(d.avg_exec_us) <= min(float(l.avg_exec_us),
                                       float(e.avg_exec_us)) * 1.02:
            das_best += 1
        if ri in LOW_RATES:
            sp_vs_etf.append(float(e.avg_exec_us) / float(d.avg_exec_us))
            edp_vs_etf.append(1 - float(de.edp) / float(e.edp))
        else:
            sp_vs_lut.append(float(l.avg_exec_us) / float(d.avg_exec_us))
            edp_vs_lut.append(1 - float(de.edp) / float(l.edp))
    us = time.perf_counter() - t0
    out = {
        "speedup_vs_etf_low": float(np.mean(sp_vs_etf)),
        "edp_red_vs_etf_low": float(np.mean(edp_vs_etf)),
        "speedup_vs_lut_high": float(np.mean(sp_vs_lut)),
        "edp_red_vs_lut_high": float(np.mean(edp_vs_lut)),
        "das_matches_best_frac": das_best / cells,
        "n_mixes": len(mixes), "us_per_call": us,
    }
    if csv:
        print(f"summary40,{us*1e6:.0f},"
              f"{out['speedup_vs_etf_low']:.3f}|{out['edp_red_vs_etf_low']:.3f}"
              f"|{out['speedup_vs_lut_high']:.3f}|"
              f"{out['edp_red_vs_lut_high']:.3f}")
    else:
        print(f"over {len(mixes)} workload mixes "
              f"({cells} cells, {us:.0f}s):")
        print(f"  low rates:  DAS vs ETF speedup {out['speedup_vs_etf_low']:.2f}x "
              f"(paper 1.29x), EDP -{out['edp_red_vs_etf_low']*100:.0f}% "
              f"(paper -45%)")
        print(f"  high rates: DAS vs LUT speedup {out['speedup_vs_lut_high']:.2f}x "
              f"(paper 1.28x), EDP -{out['edp_red_vs_lut_high']*100:.0f}% "
              f"(paper -37%)")
        print(f"  DAS matches/beats the best baseline in "
              f"{out['das_matches_best_frac']*100:.0f}% of cells")
        print(f"  check: DAS>=both in >70% of cells: "
              f"{'PASS' if out['das_matches_best_frac'] > 0.7 else 'MISS'}")
    return out


if __name__ == "__main__":
    run()

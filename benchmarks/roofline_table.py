"""The dry-run roofline table (§Roofline): reads dryrun_results.json
(produced by `python -m repro.launch.dryrun --all --both-meshes`)."""
from __future__ import annotations

import json
import os

from repro.launch import roofline

PATH = os.environ.get("REPRO_DRYRUN_JSON", "/root/repo/dryrun_results.json")


def run(csv=False):
    if not os.path.exists(PATH):
        print(f"  (no {PATH}; run `python -m repro.launch.dryrun --all "
              f"--both-meshes --out {PATH}` first)")
        return []
    rows = roofline.main(PATH)
    ok = [r for r in rows if r.get("status") == "ok"]
    n_skip = sum(r.get("status") == "skipped" for r in rows)
    print(f"\n  {len(ok)} cells analyzed, {n_skip} documented skips")
    return rows


if __name__ == "__main__":
    run()

"""Fault-injection degradation curves: scheduler quality as PEs die.

Progressively fails the accelerator PEs (FFT -> FIR -> FEC -> SAP) at
t=0 and sweeps LUT / ETF / DAS over the scenarios in ONE `run_batch`
call per mode (the same workload stacked S times + `faults.stack_plans`
along the scenario axis). Graceful degradation means the latency curve
is monotone non-decreasing in the number of dead PEs and every scenario
still completes all jobs (no stalls, no drops — failures at t=0 revoke
nothing in flight, so this isolates pure scheduling degradation).

    PYTHONPATH=src python -m benchmarks.faults [--smoke] [--csv]

--smoke runs a 4-point curve with LUT/ETF only (no classifier training),
sized for CI.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from benchmarks import common
from repro.core import faults, simulator as sim, soc, workloads

# the cell to degrade: a mid-load mix x rate (latency-sensitive but not
# saturated, so extra CPU pressure is visible without stalling)
MIX_IDX, RATE_IDX = 5, 6

# kill accelerators in cluster order: FFT(4) -> FIR(4) -> FEC(1) -> SAP(2)
ACCEL_PES = np.where(soc.PE_CLUSTER >= soc.FFT_ACC)[0]

# tolerated non-monotonicity: a k+1 point may undercut point k by 2%
# (re-placement can shift NoC traffic in the survivors' favor slightly)
MONO_TOL = 1.02


def _plan_for(k: int) -> faults.FaultPlan:
    plan = faults.healthy_plan()
    if k:
        plan = faults.fail_pes(plan, ACCEL_PES[:k].tolist(), at=0.0)
    return plan


def _curve(mode: int, wl_b, plan_b, tree=None) -> List[sim.SimResult]:
    # through the crash-safe campaign runner, like every benchmark grid
    res = common.sweep(mode, wl_b, tree=tree, plan=plan_b,
                       label=f"faults mode {mode}")
    n = int(np.asarray(plan_b.pe_fail_at).shape[0])
    return [sim.result_at(res, k) for k in range(n)]


def _monotone(avg: List[float]) -> bool:
    return all(b >= a / MONO_TOL for a, b in zip(avg, avg[1:]))


def run(csv: bool = False, smoke: bool = False) -> Dict:
    ks = [0, 4, 8, len(ACCEL_PES)] if smoke else list(range(len(ACCEL_PES) + 1))
    wl = common.suite().build(MIX_IDX, RATE_IDX)
    wl_b = workloads.stack_workloads([wl] * len(ks))
    plan_b = faults.stack_plans([_plan_for(k) for k in ks])

    sweeps = [("LUT", sim.MODE_LUT, None), ("ETF", sim.MODE_ETF, None)]
    if not smoke:
        sweeps.append(("DAS", sim.MODE_DAS, common.das_policy().tree))

    t0 = time.perf_counter()
    out: Dict[str, List[sim.SimResult]] = {
        name: _curve(mode, wl_b, plan_b, tree=tree)
        for name, mode, tree in sweeps
    }
    us = time.perf_counter() - t0

    ok = True
    curves = {}
    for name, results in out.items():
        avg = [float(r.avg_exec_us) for r in results]
        edp = [float(r.edp) for r in results]
        drops = [int(r.n_dropped_jobs) for r in results]
        retries = [int(r.n_retries) for r in results]
        stalls = [bool(r.stalled) for r in results]
        mono = _monotone(avg)
        healthy = not any(stalls) and not any(drops)
        ok = ok and mono and healthy
        curves[name] = {"k": ks, "avg_exec_us": avg, "edp": edp,
                        "dropped_jobs": drops, "retries": retries,
                        "monotone": mono}
        if not csv:
            pts = "  ".join(f"k={k}:{a:7.2f}" for k, a in zip(ks, avg))
            print(f"{name:4s} avg exec (us) vs dead accel PEs: {pts}")
            print(f"     EDP x{edp[-1]/edp[0]:.2f} at full accel loss; "
                  f"drops={sum(drops)} retries={sum(retries)} "
                  f"stalls={sum(stalls)}  "
                  f"monotone: {'PASS' if mono else 'MISS'}")
    if csv:
        slope = {n: c["avg_exec_us"][-1] / c["avg_exec_us"][0]
                 for n, c in curves.items()}
        deg = "|".join(f"{n}:{s:.3f}" for n, s in slope.items())
        print(f"faults,{us*1e6:.0f},{deg}")
    else:
        print(f"  check: degradation curves monotone, no stalls/drops: "
              f"{'PASS' if ok else 'MISS'}")
    return {"curves": curves, "ok": ok}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="4-point curve, LUT/ETF only (CI-sized)")
    args = ap.parse_args(argv)
    res = run(csv=args.csv, smoke=args.smoke)
    if not res["ok"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

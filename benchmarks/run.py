"""Benchmark harness entry point: one section per paper table/figure plus
the beyond-paper serving and roofline benchmarks. Prints
``name,us_per_call,derived`` CSV lines with --csv; --json PATH additionally
writes a machine-readable `BENCH_sweep.json`-style record (per-section wall
time, each section's returned metrics, and the derived DAS speedup / EDP
reductions vs LUT and ETF) so the perf trajectory is comparable across PRs.

    PYTHONPATH=src python -m benchmarks.run [--csv] [--json PATH]
                                            [--only fig2,fig3,...]
                                            [--resume DIR]

--resume DIR checkpoints every sweep's chunks into DIR (atomic
write-temp + rename); re-running the same command after a crash or
SIGKILL resumes from the completed chunks and produces byte-identical
results. The --json record gains a "campaign" block (retries, timeouts,
OOM shrink events, stall trips, chunk reuse, per-chunk wall time), and
the record itself is written atomically.

Environment: REPRO_BENCH_INSTANCES (default 60) scales workload size;
REPRO_BENCH_FULL=0 opts out of the full 40 mixes x 14 rates grid;
REPRO_BENCH_BATCH / REPRO_BENCH_DEVICES control sweep chunking and
scenario-axis sharding; REPRO_BENCH_CAMPAIGN_DIR / REPRO_BENCH_WATCHDOG_S
/ REPRO_BENCH_STEP_BUDGET configure the crash-safe campaign layer (see
benchmarks.common).
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (faults, fig2, fig3, heuristic, overhead,
                        roofline_table, serving_das, summary40, table2)

SECTIONS = [
    ("fig2", "Fig.2: exec time + EDP, 3 workloads x 4 schedulers", fig2.run),
    ("fig3", "Fig.3: DAS decision mix + scheduling energy", fig3.run),
    ("table2", "Table II: classifier accuracy/storage", table2.run),
    ("summary40", "40-workload summary claims", summary40.run),
    ("heuristic", "static-threshold heuristic comparison", heuristic.run),
    ("overhead", "scheduling overhead anchors", overhead.run),
    ("faults", "fault-injection degradation curves", faults.run),
    ("serving_das", "beyond-paper: DAS serving dispatch", serving_das.run),
    ("roofline", "dry-run roofline table", roofline_table.run),
]


def _jsonable(obj):
    """Best-effort JSON coercion for numpy scalars/arrays in section
    results; anything else degrades to its repr rather than crashing the
    record write at the end of a long run."""
    import numpy as np
    if isinstance(obj, (np.floating, np.integer, np.bool_)):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return repr(obj)


def _derived(results: dict) -> dict:
    """Headline DAS-vs-baseline metrics (paper IV-C), lifted from the
    summary40 section when it ran: speedup and EDP reduction vs ETF at low
    rates and vs LUT at high rates."""
    s40 = results.get("summary40", {}).get("result")
    if not isinstance(s40, dict):
        return {}
    keys = ("speedup_vs_etf_low", "edp_red_vs_etf_low",
            "speedup_vs_lut_high", "edp_red_vs_lut_high",
            "das_matches_best_frac")
    return {k: s40[k] for k in keys if k in s40}


def _env_record() -> dict:
    import os

    import jax

    from benchmarks import common
    return {
        "backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "bench_devices": os.environ.get("REPRO_BENCH_DEVICES"),
        "batch_size": common.batch_size(),
        "full_grid": common.FULL,
        "n_instances": common.N_INSTANCES,
        "train_grid": [len(common.TRAIN_MIXES), len(common.TRAIN_RATES)],
    }


def _kernel_record() -> dict:
    """Which decision-path backend ran, how often each primitive traced,
    and how often the whole simulator retraced (a nonzero retrace count
    across a warm sweep session is a caching bug)."""
    from repro.core import simulator as sim
    from repro.kernels.etf_ft import ops as kops
    return {
        "mode": kops.kernel_mode(),
        "dispatch_count": dict(kops.DISPATCH_COUNT),
        "trace_count": dict(sim.TRACE_COUNT),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", action="store_true",
                    help="emit name,us_per_call,derived CSV lines")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-section wall times + metrics to PATH")
    ap.add_argument("--only", default=None)
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="checkpoint sweep chunks into DIR and resume any "
                         "completed chunks from a previous (killed) run")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import common
    if args.resume:
        common.set_campaign_dir(args.resume)

    t00 = time.time()
    failures = []
    results = {}
    for name, title, fn in SECTIONS:
        if only and name not in only:
            continue
        print(f"\n{'='*72}\n== {name}: {title}\n{'='*72}")
        t0 = time.time()
        try:
            out = fn(csv=args.csv)
            results[name] = {"wall_s": round(time.time() - t0, 3),
                             "result": out}
        except Exception as e:
            failures.append((name, e))
            results[name] = {"wall_s": round(time.time() - t0, 3),
                             "error": f"{type(e).__name__}: {e}"}
            traceback.print_exc()
        print(f"-- {name} done in {time.time()-t0:.0f}s")
    total = time.time() - t00
    print(f"\nall benchmarks done in {total:.0f}s; "
          f"{len(failures)} failures")
    if args.json:
        record = {
            "total_s": round(total, 3),
            "env": _env_record(),
            "derived": _derived(results),
            "campaign": common.campaign_stats(),
            "kernels": _kernel_record(),
            "sections": results,
        }
        # atomic write (temp + rename): a crash mid-dump never leaves a
        # truncated BENCH_sweep.json behind
        from repro.core import campaign
        campaign.atomic_write_json(args.json, record, default=_jsonable)
        print(f"wrote {args.json}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

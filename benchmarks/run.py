"""Benchmark harness entry point: one section per paper table/figure plus
the beyond-paper serving and roofline benchmarks. Prints
``name,us_per_call,derived`` CSV lines with --csv.

    PYTHONPATH=src python -m benchmarks.run [--csv] [--only fig2,fig3,...]

Environment: REPRO_BENCH_INSTANCES (default 60) scales workload size;
REPRO_BENCH_FULL=1 runs all 40 mixes x 14 rates for training/eval.
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (faults, fig2, fig3, heuristic, overhead,
                        roofline_table, serving_das, summary40, table2)

SECTIONS = [
    ("fig2", "Fig.2: exec time + EDP, 3 workloads x 4 schedulers", fig2.run),
    ("fig3", "Fig.3: DAS decision mix + scheduling energy", fig3.run),
    ("table2", "Table II: classifier accuracy/storage", table2.run),
    ("summary40", "40-workload summary claims", summary40.run),
    ("heuristic", "static-threshold heuristic comparison", heuristic.run),
    ("overhead", "scheduling overhead anchors", overhead.run),
    ("faults", "fault-injection degradation curves", faults.run),
    ("serving_das", "beyond-paper: DAS serving dispatch", serving_das.run),
    ("roofline", "dry-run roofline table", roofline_table.run),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", action="store_true",
                    help="emit name,us_per_call,derived CSV lines")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    t00 = time.time()
    failures = []
    for name, title, fn in SECTIONS:
        if only and name not in only:
            continue
        print(f"\n{'='*72}\n== {name}: {title}\n{'='*72}")
        t0 = time.time()
        try:
            fn(csv=args.csv)
        except Exception as e:
            failures.append((name, e))
            traceback.print_exc()
        print(f"-- {name} done in {time.time()-t0:.0f}s")
    print(f"\nall benchmarks done in {time.time()-t00:.0f}s; "
          f"{len(failures)} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Scheduling-overhead microbenchmark (paper I / IV-C anchors): per-decision
latency and energy of LUT, ETF, the DAS classifier, plus the measured
wall-time of the ETF finish-time search (jnp oracle vs Pallas kernel in
interpret mode — the TPU kernel's semantics)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import simulator as sim, soc
from repro.kernels.etf_ft import kernel as ek, ref as er


def run(csv=False):
    pol = common.das_policy()
    res = common.eval_cell(5, 12, sim.MODE_DAS, tree=pol.tree)
    n = max(int(res.n_decisions), 1)
    rows = {
        "LUT_ns": float(soc.LUT_LATENCY_US) * 1e3,
        "LUT_nJ": float(soc.LUT_ENERGY_UJ) * 1e3,
        "ETF_ns_q8": float(soc.etf_latency_us(8)) * 1e3,
        "DAS_heavy_ns": float(res.sched_time_us) / n * 1e3,
        "DAS_heavy_nJ": float(res.sched_energy_uj) / n * 1e3,
    }

    # ETF finish-time search wall-time: jnp oracle (jitted, CPU)
    B, R, P = 64, 64, 19
    key = jax.random.PRNGKey(0)
    avail = jax.random.uniform(key, (B, R, P)) * 10
    free = jax.random.uniform(key, (B, P)) * 10
    ex = jax.random.uniform(key, (B, R, P)) * 5
    now = jnp.zeros((B,))
    f = jax.jit(er.etf_ft_reference)
    f(avail, free, ex, now)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f(avail, free, ex, now)[0].block_until_ready()
    rows["etf_ft_jnp_us_per_batch64"] = (time.perf_counter() - t0) / 20 * 1e6

    for k, v in rows.items():
        if csv:
            print(f"overhead,{v:.1f},{k}")
        else:
            print(f"  {k:28s} {v:10.1f}")
    print(f"  paper anchors: LUT 6 ns / 2.3 nJ; DAS heavy ~65 ns / 27.2 nJ")
    return rows


if __name__ == "__main__":
    run()

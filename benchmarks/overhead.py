"""Scheduling-overhead microbenchmark (paper I / IV-C anchors): per-decision
latency and energy of LUT, ETF, the DAS classifier, plus the measured
wall-time of the ETF finish-time search (jnp oracle vs Pallas kernel in
interpret mode — the TPU kernel's semantics)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import simulator as sim, soc
from repro.kernels.etf_ft import kernel as ek, ops as eo, ref as er


def _time_us(f, *args, reps=20):
    """Warm once (compile), then report mean wall time per call in us."""
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv=False):
    pol = common.das_policy()
    res = common.eval_cell(5, 12, sim.MODE_DAS, tree=pol.tree)
    n = max(int(res.n_decisions), 1)
    rows = {
        "LUT_ns": float(soc.LUT_LATENCY_US) * 1e3,
        "LUT_nJ": float(soc.LUT_ENERGY_UJ) * 1e3,
        "ETF_ns_q8": float(soc.etf_latency_us(8)) * 1e3,
        "DAS_heavy_ns": float(res.sched_time_us) / n * 1e3,
        "DAS_heavy_nJ": float(res.sched_energy_uj) / n * 1e3,
    }

    # ETF finish-time search wall-time, batch of 64 decisions: the jnp
    # oracle AND the kernel dispatch path (Pallas native on TPU, interpret
    # elsewhere — interpret is a correctness path, so its time is reported
    # for scaling context, not as a win)
    B, R, P = 64, 64, 19
    key = jax.random.PRNGKey(0)
    avail = jax.random.uniform(key, (B, R, P)) * 10
    free = jax.random.uniform(key, (B, P)) * 10
    ex = jax.random.uniform(key, (B, R, P)) * 5
    now = jnp.zeros((B,))
    interpret = jax.default_backend() != "tpu"
    kreps = 3 if interpret else 20
    rows["etf_ft_jnp_us_per_batch64"] = _time_us(
        jax.jit(er.etf_ft_reference), avail, free, ex, now)
    rows["etf_ft_kernel_us_per_batch64"] = _time_us(
        lambda *a: ek.etf_ft_search(*a, interpret=interpret),
        avail, free, ex, now, reps=kreps)

    # scenario-batched masked variant (the decision hot path the
    # simulator routes through under REPRO_SIM_KERNELS)
    slot_ok = jnp.ones((B, R), bool)
    alive = jnp.ones((B, P), bool)
    rows["etf_ft_masked_xla_us_per_batch64"] = _time_us(
        jax.jit(er.etf_ft_masked_reference),
        avail, free, ex, now, slot_ok, alive)
    rows["etf_ft_masked_kernel_us_per_batch64"] = _time_us(
        lambda *a: ek.etf_ft_search_masked(*a, interpret=interpret),
        avail, free, ex, now, slot_ok, alive, reps=kreps)

    for k, v in rows.items():
        if csv:
            print(f"overhead,{v:.1f},{k}")
        else:
            print(f"  {k:28s} {v:10.1f}")
    print(f"  paper anchors: LUT 6 ns / 2.3 nJ; DAS heavy ~65 ns / 27.2 nJ")
    return rows


if __name__ == "__main__":
    run()

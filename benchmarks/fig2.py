"""Fig. 2 reproduction: average execution time (a-c) and EDP (d-f) of DAS,
LUT, ETF, ETF-ideal for three representative workloads across data rates.

Workload selection mirrors the paper: workload-1 = low data-rate behavior
(temporal-mitigation-dominated mix, never congests), workload-2 = moderate
(wifi-rx-dominated: scarce-FEC contention, the ETF-wins regime),
workload-3 = high rate (app-1-heavy: ETF's quadratic overhead collapses,
DAS falls back to LUT).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import workloads

# mix indices in workloads.workload_mixes(): 3 = temporal-only,
# 1 = wifi-rx-only, 4 = app1-only
WL = [("workload-1 (low rate: temporal)", 3),
      ("workload-2 (moderate: wifi-rx)", 1),
      ("workload-3 (high rate: app-1)", 4)]
RATE_IDX = [0, 3, 5, 7, 9, 10, 11, 12, 13]


def run(csv=False):
    rows = []
    for title, mi in WL:
        if not csv:
            print(f"\n== {title} ==")
            print(f"{'rate':>7} | {'LUT':>8} {'ETF':>8} {'DAS':>8} "
                  f"{'DAS-FS':>8} {'ETFideal':>8} | {'EDP LUT':>9} "
                  f"{'EDP ETF':>9} {'EDP DAS-FS':>10}")
        # one batched sweep per mode over this workload's rate axis
        t0 = time.perf_counter()
        grid = common.eval_modes_grid([(mi, ri) for ri in RATE_IDX],
                                      with_fs=True)
        us = (time.perf_counter() - t0) / len(RATE_IDX)
        for idx, ri in enumerate(RATE_IDX):
            res = {name: per_cell[idx] for name, per_cell in grid.items()}
            rate = float(workloads.DATA_RATES_MBPS[ri])
            r = {"workload": title, "rate_mbps": rate, "us_per_call": us,
                 **{f"exec_{k}": float(v.avg_exec_us)
                    for k, v in res.items()},
                 **{f"edp_{k}": float(v.edp) for k, v in res.items()}}
            rows.append(r)
            if csv:
                print(f"fig2,{us*1e6:.0f},"
                      f"{title}|{rate}|{r['exec_DAS-FS']:.3f}")
            else:
                print(f"{rate:7.1f} | {r['exec_LUT']:8.2f} "
                      f"{r['exec_ETF']:8.2f} {r['exec_DAS']:8.2f} "
                      f"{r['exec_DAS-FS']:8.2f} "
                      f"{r['exec_ETF-ideal']:8.2f} | {r['edp_LUT']:9.0f} "
                      f"{r['edp_ETF']:9.0f} {r['edp_DAS-FS']:10.0f}")
    # paper-claim checks (trend-level)
    by_wl = {}
    for r in rows:
        by_wl.setdefault(r["workload"], []).append(r)
    checks = []
    lo = by_wl[WL[0][0]][0]
    checks.append(("low-rate: DAS <= ETF exec",
                   lo["exec_DAS"] <= lo["exec_ETF"] * 1.02))
    checks.append(("low-rate: DAS EDP well below ETF EDP",
                   lo["edp_DAS"] < 0.7 * lo["edp_ETF"]))
    mid = by_wl[WL[1][0]][-3]
    checks.append(("moderate: DAS <= LUT exec",
                   mid["exec_DAS"] <= mid["exec_LUT"] * 1.02))
    hi = by_wl[WL[2][0]][-1]
    checks.append(("high-rate wl3: DAS-FS ~ LUT (ETF collapses)",
                   hi["exec_DAS-FS"] <= hi["exec_LUT"] * 1.15))
    for name, ok in checks:
        print(f"  check: {name}: {'PASS' if ok else 'MISS'}")
    print("  note: the paper's exact (rate, big-avail) pair cannot separate"
          " the app-1 regime\n  on our synthesized profiles; the paper's own"
          " feature-selection step (IV-B) picks\n  (head task type, LITTLE "
          "utilization) and recovers the workload-3 behavior (DAS-FS).")
    return rows


if __name__ == "__main__":
    run()

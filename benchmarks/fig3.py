"""Fig. 3 reproduction: DAS decision split (fast vs slow) per data rate and
the total scheduling-energy overhead of LUT, ETF and DAS (uniform mix)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import simulator as sim, workloads

MIX = 5  # uniform five-app mix


def run(csv=False):
    pol = common.das_policy()
    rows = []
    print(f"{'rate':>7} | {'fast%':>6} {'slow%':>6} | "
          f"{'E_LUT uJ':>9} {'E_ETF uJ':>9} {'E_DAS uJ':>9} | "
          f"{'DAS ns/dec':>10} {'DAS nJ/dec':>10}")
    all_rates = range(len(workloads.DATA_RATES_MBPS))
    t0 = time.perf_counter()
    grid = common.eval_modes_grid([(MIX, ri) for ri in all_rates])
    us = (time.perf_counter() - t0) / len(workloads.DATA_RATES_MBPS)
    for ri in all_rates:
        res = {name: per_cell[ri] for name, per_cell in grid.items()}
        d = res["DAS"]
        n = max(int(d.n_decisions), 1)
        fast = int(d.n_fast) / n
        rate = float(workloads.DATA_RATES_MBPS[ri])
        lat_ns = float(d.sched_time_us) / n * 1e3
        e_nj = float(d.sched_energy_uj) / n * 1e3
        rows.append({
            "rate_mbps": rate, "fast_frac": fast, "slow_frac": 1 - fast,
            "sched_e_lut": float(res["LUT"].sched_energy_uj),
            "sched_e_etf": float(res["ETF"].sched_energy_uj),
            "sched_e_das": float(d.sched_energy_uj),
            "das_ns_per_decision": lat_ns,
            "das_nj_per_decision": e_nj,
            "us_per_call": us,
        })
        if csv:
            print(f"fig3,{us*1e6:.0f},{rate}|{fast:.3f}|{e_nj:.2f}")
        else:
            print(f"{rate:7.1f} | {fast:6.2f} {1-fast:6.2f} | "
                  f"{rows[-1]['sched_e_lut']:9.3f} "
                  f"{rows[-1]['sched_e_etf']:9.3f} "
                  f"{rows[-1]['sched_e_das']:9.3f} | "
                  f"{lat_ns:10.1f} {e_nj:10.2f}")
    lo, hi = rows[0], rows[-1]
    print(f"  check: lowest rate uses fast for "
          f"{lo['fast_frac']*100:.0f}% (paper: 100%): "
          f"{'PASS' if lo['fast_frac'] > 0.95 else 'MISS'}")
    print(f"  paper anchors: DAS heavy-load ~65 ns / 27.2 nJ per decision; "
          f"ours at top rate: {hi['das_ns_per_decision']:.0f} ns / "
          f"{hi['das_nj_per_decision']:.1f} nJ")
    return rows


if __name__ == "__main__":
    run()

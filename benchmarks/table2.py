"""Table II reproduction: classification accuracy + storage for LR and DT
classifiers vs number of features (our profiles; same methodology).

The training profiles come from `common.dataset()`, i.e. the batched
two-execution oracle sweep (`oracle.generate` via `sim.run_batch`)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import classifier as clf, oracle, simulator as sim


def run(csv=False):
    ds = common.dataset()
    tr, te = oracle.train_test_split(ds)
    sub = np.random.RandomState(0).permutation(len(tr))[:20000]
    Xs, ys = tr.features[sub], tr.labels[sub]

    scores = clf.feature_scores(Xs[:4000], ys[:4000], depth=2)
    order = np.argsort(-scores)
    top6 = [int(i) for i in order[:6]]
    paper2 = [sim.FEAT_RATE, sim.FEAT_BIG_AVAIL]

    rows = []

    def add(name, model, cols, t0):
        acc = model.accuracy(te.features[:, cols], te.labels)
        rows.append({
            "classifier": name, "n_features": len(cols),
            "accuracy": acc, "storage_kb": model.storage_kb(),
            "us_per_call": time.perf_counter() - t0,
        })

    t0 = time.perf_counter()
    add("LR (2 feat, paper pair)", clf.LogisticRegression.fit(
        Xs[:, paper2], ys), paper2, t0)
    t0 = time.perf_counter()
    all_cols = list(range(Xs.shape[1]))
    add("LR (62 feat)", clf.LogisticRegression.fit(Xs, ys), all_cols, t0)
    t0 = time.perf_counter()
    add("DT d2 (1 feat: rate)", clf.DecisionTree.fit(
        Xs[:, [sim.FEAT_RATE]], ys, 2), [sim.FEAT_RATE], t0)
    t0 = time.perf_counter()
    add("DT d2 (2 feat, paper pair)", clf.DecisionTree.fit(
        Xs[:, paper2], ys, 2), paper2, t0)
    t0 = time.perf_counter()
    add("DT d2 (2 feat, selected)", clf.DecisionTree.fit(
        Xs[:, top6[:2]], ys, 2), top6[:2], t0)
    t0 = time.perf_counter()
    add("DT d4 (6 feat)", clf.DecisionTree.fit(
        Xs[:, top6], ys, 4), top6, t0)
    t0 = time.perf_counter()
    add("DT d16 (62 feat)", clf.DecisionTree.fit(Xs, ys, 16), all_cols, t0)

    print(f"{'classifier':28s} {'#feat':>5} {'acc%':>7} {'KB':>8}")
    for r in rows:
        if csv:
            print(f"table2,{r['us_per_call']*1e6:.0f},"
                  f"{r['classifier']}|{r['accuracy']*100:.2f}|"
                  f"{r['storage_kb']:.3f}")
        else:
            print(f"{r['classifier']:28s} {r['n_features']:5d} "
                  f"{r['accuracy']*100:7.2f} {r['storage_kb']:8.3f}")
    print(f"  top-6 selected features: "
          f"{[sim.FEAT_NAMES[i] for i in top6]}")
    d16 = rows[-1]["accuracy"]
    d2 = rows[4]["accuracy"]
    print(f"  check: deep tree >= shallow tree accuracy: "
          f"{'PASS' if d16 >= d2 - 0.02 else 'MISS'}")
    print(f"  check: shallow DT storage << deep DT storage: "
          f"{'PASS' if rows[3]['storage_kb'] < rows[-1]['storage_kb']/100 else 'MISS'}")
    return rows


if __name__ == "__main__":
    run()

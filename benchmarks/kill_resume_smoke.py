"""Kill-and-resume smoke test for the campaign layer (CI, both jobs).

Proves the crash-safety claim end to end with a real SIGKILL:

  1. compute an uninterrupted reference sweep in-process (`sim.run_batch`);
  2. launch a child process running the same sweep as a checkpointed
     campaign, throttled (`chunk_delay_s`) so chunks land one at a time;
  3. SIGKILL the child once some — but not all — chunks are checkpointed;
  4. resume the campaign in-process and assert (a) completed chunks were
     reused, not recomputed, and (b) every `SimResult` field is
     byte-identical to the uninterrupted reference.

    PYTHONPATH=src python -m benchmarks.kill_resume_smoke [--dir DIR]

Exit status 0 on success. `--child DIR` is the internal child entry.
"""
from __future__ import annotations

import argparse
import glob
import os
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.core import campaign as camp, simulator as sim, workloads

MODE = sim.MODE_LUT
N_INSTANCES = 5
CELLS = [(mi, ri) for mi in range(4) for ri in (0, 5, 9, 13)]  # 16 scenarios
BATCH = 2                                                      # -> 8 chunks
CHUNK_DELAY_S = 0.6


def _workloads():
    suite = workloads.default_suite(n_instances=N_INSTANCES)
    return [suite.build(mi, ri) for mi, ri in CELLS]


def child(cdir: str) -> None:
    """Run the campaign slowly so the parent can SIGKILL it mid-grid."""
    camp.run_campaign(MODE, _workloads(), batch_size=BATCH,
                      checkpoint_dir=cdir, chunk_delay_s=CHUNK_DELAY_S)


def _chunk_files(cdir: str):
    return glob.glob(os.path.join(cdir, "*", "chunk_*.npz"))


def main(cdir: str) -> None:
    wls = _workloads()
    n_chunks = -(-len(CELLS) // BATCH)
    print(f"# reference sweep: {len(CELLS)} scenarios, {n_chunks} chunks")
    ref = sim.run_batch(MODE, wls, batch_size=BATCH)

    print("# launching child campaign (throttled)...")
    proc = subprocess.Popen(
        [sys.executable, "-m", "benchmarks.kill_resume_smoke",
         "--child", cdir],
        env={**os.environ,
             "PYTHONPATH": os.pathsep.join(
                 p for p in ("src", os.environ.get("PYTHONPATH", "")) if p)})
    deadline = time.time() + 300
    try:
        while True:
            done = len(_chunk_files(cdir))
            if done >= 2:
                break
            if proc.poll() is not None:
                raise SystemExit(
                    f"child exited early (rc={proc.returncode}) with only "
                    f"{done} chunk(s) checkpointed — widen CHUNK_DELAY_S?")
            if time.time() > deadline:
                raise SystemExit("timed out waiting for the first chunks")
            time.sleep(0.05)
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait()
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()

    done = len(_chunk_files(cdir))
    print(f"# SIGKILLed child after {done}/{n_chunks} chunks")
    if done >= n_chunks:
        raise SystemExit("child finished before the kill — not a mid-grid "
                         "interruption; widen CHUNK_DELAY_S")

    print("# resuming in-process...")
    out = camp.run_campaign(MODE, wls, batch_size=BATCH, checkpoint_dir=cdir)
    assert out.stats["chunks_reused"] >= done - 1, out.stats
    assert out.stats["chunks_reused"] < n_chunks, out.stats
    assert out.stats["chunks_computed"] + out.stats["chunks_reused"] \
        == n_chunks, out.stats
    for name in sim.SimResult._fields:
        a = np.asarray(getattr(ref, name))
        b = np.asarray(getattr(out.result, name))
        assert a.tobytes() == b.tobytes(), \
            f"field {name} differs after resume"
    print(f"# resume reused {out.stats['chunks_reused']} chunk(s), "
          f"recomputed {out.stats['chunks_computed']}; all "
          f"{len(sim.SimResult._fields)} result fields byte-identical "
          "to the uninterrupted sweep: PASS")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=None,
                    help="campaign dir (default: a fresh temp dir)")
    ap.add_argument("--child", default=None, metavar="DIR",
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child:
        child(args.child)
    elif args.dir:
        main(args.dir)
    else:
        with tempfile.TemporaryDirectory(prefix="repro-campaign-") as d:
            main(d)

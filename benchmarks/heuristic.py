"""The static data-rate-threshold heuristic comparison (paper IV-C): DAS
should beat a judiciously-chosen fixed threshold across rates."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import simulator as sim, workloads

MIXES = [0, 1, 3, 4, 5]


def _best_threshold() -> float:
    """Choose the threshold from training data (as the paper does)."""
    ds = common.dataset()
    rates = np.unique(ds.rates)
    best, best_rate = None, rates[0]
    for thr in rates:
        pred = (ds.features[:, sim.FEAT_RATE] >= thr).astype(int)
        acc = (pred == ds.labels).mean()
        if best is None or acc > best:
            best, best_rate = acc, thr
    return float(best_rate)


def run(csv=False):
    thr = _best_threshold()
    pol = common.das_policy()
    das_wins = 0
    total = 0
    gains = []
    t0 = time.perf_counter()
    cells = [(mi, ri) for mi in MIXES for ri in [0, 3, 5, 7, 9, 11, 13]]
    # batched sweeps: one DAS grid, one static-threshold grid
    d_grid = common.eval_grid(cells, sim.MODE_DAS, tree=pol.tree)
    h_grid = common.eval_grid(cells, sim.MODE_THRESHOLD, rate_threshold=thr)
    for d, h in zip(d_grid, h_grid):
        total += 1
        gain = float(h.avg_exec_us) / float(d.avg_exec_us)
        gains.append(gain)
        if gain >= 1.0:
            das_wins += 1
    us = time.perf_counter() - t0
    mean_gain = float(np.mean(gains))
    if csv:
        print(f"heuristic,{us*1e6:.0f},{thr}|{mean_gain:.4f}")
    else:
        print(f"threshold={thr:.0f} Mbps (fit on training data)")
        print(f"  DAS vs heuristic mean exec-time ratio: {mean_gain:.3f} "
              f"(paper: 13% lower => 1.13); DAS wins/ties {das_wins}/{total}")
        print(f"  check: DAS >= heuristic on average: "
              f"{'PASS' if mean_gain >= 1.0 else 'MISS'}")
    return {"threshold": thr, "mean_gain": mean_gain,
            "das_wins": das_wins, "total": total}


if __name__ == "__main__":
    run()

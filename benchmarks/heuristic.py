"""The static data-rate-threshold heuristic comparison (paper IV-C): DAS
should beat a judiciously-chosen fixed threshold across rates.

The "judicious" choice is made by simulation, the way a practitioner
would: every candidate threshold (the distinct training data rates) is
evaluated on a selection grid in ONE batched `run_batch` call, using the
leading-`[S]` scenario axis on `rate_threshold` — the grid is tiled once
per candidate and each lane carries its own threshold, so the whole
candidate ladder costs a single sharded sweep instead of a per-threshold
Python loop."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import simulator as sim, workloads

MIXES = [0, 1, 3, 4, 5]
# selection grid for picking the threshold (distinct from the eval grid
# below, like the paper's train/eval split)
SELECT_RATES = [1, 5, 9, 13]


def _best_threshold() -> float:
    """Simulation-chosen static threshold: one batched sweep over
    (candidate x mix x rate), lowest mean exec time wins."""
    cand = np.unique(np.asarray(common.dataset().rates, np.float32))
    cells = [(mi, ri) for mi in MIXES for ri in SELECT_RATES]
    stacked = workloads.stack_workloads(
        [common._cell_workload(mi, ri) for mi, ri in cells] * len(cand))
    thr_axis = np.repeat(cand, len(cells)).astype(np.float32)
    # one crash-safe campaign over the whole candidate ladder
    res = common.sweep(sim.MODE_THRESHOLD, stacked,
                       rate_threshold=thr_axis, label="heuristic-select")
    per_cand = np.asarray(res.avg_exec_us).reshape(len(cand), len(cells))
    return float(cand[np.argmin(per_cand.mean(axis=1))])


def run(csv=False):
    thr = _best_threshold()
    pol = common.das_policy()
    das_wins = 0
    total = 0
    gains = []
    t0 = time.perf_counter()
    cells = [(mi, ri) for mi in MIXES for ri in [0, 3, 5, 7, 9, 11, 13]]
    # batched sweeps: one DAS grid, one static-threshold grid
    d_grid = common.eval_grid(cells, sim.MODE_DAS, tree=pol.tree)
    h_grid = common.eval_grid(cells, sim.MODE_THRESHOLD, rate_threshold=thr)
    for d, h in zip(d_grid, h_grid):
        total += 1
        gain = float(h.avg_exec_us) / float(d.avg_exec_us)
        gains.append(gain)
        if gain >= 1.0:
            das_wins += 1
    us = time.perf_counter() - t0
    mean_gain = float(np.mean(gains))
    if csv:
        print(f"heuristic,{us*1e6:.0f},{thr}|{mean_gain:.4f}")
    else:
        print(f"threshold={thr:.0f} Mbps (simulation-fit on the selection "
              "grid, one batched candidate sweep)")
        print(f"  DAS vs heuristic mean exec-time ratio: {mean_gain:.3f} "
              f"(paper: 13% lower => 1.13); DAS wins/ties {das_wins}/{total}")
        # the baseline is now the *best possible* static threshold (picked
        # by exhaustive simulation, not the paper's hand choice), so the
        # bar is matching it on average and winning most cells
        ok = mean_gain >= 0.99 and das_wins * 2 >= total
        print(f"  check: DAS matches the simulation-fit optimum and "
              f"wins/ties most cells: {'PASS' if ok else 'MISS'}")
    return {"threshold": thr, "mean_gain": mean_gain,
            "das_wins": das_wins, "total": total}


if __name__ == "__main__":
    run()

"""Beyond-paper benchmark: DAS dispatch in the LM serving engine
(DESIGN.md section 3). Heterogeneous replica pool (the serving analog of
big.LITTLE + accelerators), request rate sweep, LUT vs ETF vs DAS."""
from __future__ import annotations

import time

import numpy as np

from repro import configs
from repro.serve import costmodel as cm
from repro.serve import dispatch as dsp
from repro.serve import engine as eng


def run(csv=False, arch="yi-34b"):
    cfg = eng.EngineConfig(n_replicas=4, max_batch=16)
    spec = cm.ReplicaSpec("v5e-8", n_chips=8)
    mc = cm.ModelCost.from_config(configs.get_config(arch))

    t0 = time.perf_counter()
    scen = [(r, 150, s) for r in (2, 8, 20, 50, 120, 300) for s in (0, 1)]
    das = dsp.train_das_dispatcher(scen, cfg, spec, mc)
    train_s = time.perf_counter() - t0

    rows = []
    beats = 0
    print(f"(DAS dispatcher: acc {das.train_accuracy:.3f}, trained in "
          f"{train_s:.0f}s)")
    print(f"{'rate':>6} | {'LUT ms':>8} {'ETF ms':>8} {'DAS ms':>8} | "
          f"{'slow%':>6} | EDP LUT/ETF/DAS")
    for rate in (2, 10, 30, 80, 200, 400):
        res = {}
        for name, d in (("LUT", dsp.LUTDispatcher(4)),
                        ("ETF", dsp.ETFDispatcher()),
                        ("DAS", dsp.DASDispatcher(das.tree, 4))):
            reqs = eng.poisson_requests(rate, 200, seed=7)
            res[name] = eng.run_engine(reqs, d, cfg, spec, mc)
        r = res["DAS"]
        sf = r.dispatch_slow / max(r.dispatch_fast + r.dispatch_slow, 1)
        best = min(res["LUT"].mean_latency_s, res["ETF"].mean_latency_s)
        if r.mean_latency_s <= best * 1.01:
            beats += 1
        rows.append({"rate": rate,
                     **{f"lat_{k}": v.mean_latency_s
                        for k, v in res.items()},
                     **{f"edp_{k}": v.edp for k, v in res.items()}})
        if csv:
            print(f"serving_das,{rate},{r.mean_latency_s*1e3:.1f}")
        else:
            print(f"{rate:6.0f} | {res['LUT'].mean_latency_s*1e3:8.1f} "
                  f"{res['ETF'].mean_latency_s*1e3:8.1f} "
                  f"{r.mean_latency_s*1e3:8.1f} | {sf:6.2f} | "
                  f"{res['LUT'].edp:8.0f}/{res['ETF'].edp:8.0f}/"
                  f"{r.edp:8.0f}")
    print(f"  check: DAS matches/beats best at >=4/6 rates: "
          f"{'PASS' if beats >= 4 else 'MISS'} ({beats}/6)")
    return rows


if __name__ == "__main__":
    run()

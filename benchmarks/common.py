"""Shared benchmark infrastructure: the trained DAS policy, the workload
suite and scheduler evaluation helpers. Results are cached in-process so
`benchmarks.run` trains the classifier once.

All (mix x rate) sweeps — oracle generation and the per-mode evaluation
grids — go through the crash-safe campaign runner (`campaign.run_campaign`
wrapping `sim.run_batch`: fixed-shape chunks, device sharding, per-chunk
retry/backoff, and — when a campaign directory is set — atomic chunk
checkpoints that a killed run resumes bit-exactly; see `sweep()`).

Environment knobs:
  REPRO_BENCH_INSTANCES  frames per workload (default 60)
  REPRO_BENCH_FULL=0     opt OUT of the paper's full 40 mixes x 14 rates
                         grid back to the 10x8 training subset (the full
                         grid is the default since the sweep went
                         sharded + streaming)
  REPRO_BENCH_BATCH      scenario-axis chunk size for batched sweeps
                         (bounds peak memory, results are independent of
                         the value). Unset, it is autotuned once per
                         process by `batch_size()`: a small timed probe
                         over a backend-keyed candidate ladder (the
                         vmapped `lax.switch`/straggler crossover differs
                         between CPU and accelerators). The probe result
                         persists in an on-disk cache keyed by
                         (backend, device count, jax version).
  REPRO_BENCH_DEVICES    number of devices `sim.run_batch` shards the
                         scenario axis over (default: all of
                         `jax.devices()`); per-scenario results are
                         independent of the device count
  REPRO_BENCH_CAMPAIGN_DIR  checkpoint campaigns into this directory
                         (equivalent to `benchmarks.run --resume DIR`)
  REPRO_BENCH_WATCHDOG_S per-chunk wall-clock watchdog (default: off)
  REPRO_BENCH_STEP_BUDGET  per-chunk device-side step budget (default:
                         off; trips retry with an escalated budget)
  REPRO_BENCH_PACK=0     opt OUT of length-aware chunk packing (scenarios
                         ordered into chunks by predicted event count so
                         fixed-shape chunks retire together; results are
                         unscattered back to grid order, so the knob only
                         moves wall time and lane occupancy)
  REPRO_SIM_KERNELS      decision-path kernel dispatch (resolved per call
                         by `repro.kernels.etf_ft.ops.kernel_mode`):
                         0/off = inline jnp, 1/auto (default) = Pallas on
                         TPU / fused XLA elsewhere, pallas = force Pallas
                         (interpret mode off-TPU), xla = force fused XLA
  REPRO_BENCH_CACHE_DIR  autotune-cache location (default
                         ~/.cache/repro)
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import campaign as camp, das, oracle, simulator as sim, \
    workloads

def _env_int(name: str, default: int) -> int:
    """Positive-integer env knob; garbage or non-positive values are
    configuration errors, not something to silently coerce."""
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return default
    try:
        val = int(raw.strip())
    except ValueError:
        raise ValueError(
            f"{name}={raw!r} is not an integer (default {default})") from None
    if val <= 0:
        raise ValueError(f"{name}={val} must be a positive integer")
    return val


def _env_opt_int(name: str) -> int | None:
    """Like `_env_int` but unset/blank means None (knob off)."""
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return None
    return _env_int(name, 0)


def _env_opt_float(name: str) -> float | None:
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return None
    try:
        val = float(raw.strip())
    except ValueError:
        raise ValueError(f"{name}={raw!r} is not a number") from None
    if val <= 0:
        raise ValueError(f"{name}={val} must be positive")
    return val


N_INSTANCES = _env_int("REPRO_BENCH_INSTANCES", 60)
# the paper's full 40 x 14 grid is the default; REPRO_BENCH_FULL=0 opts
# back out to the representative 10 x 8 training subset
FULL = os.environ.get("REPRO_BENCH_FULL", "1") != "0"

TRAIN_MIXES = list(range(40)) if FULL else [0, 1, 2, 3, 4, 5, 8, 12, 17, 22]
TRAIN_RATES = list(range(14)) if FULL else [0, 3, 5, 7, 9, 11, 12, 13]

# scenario-axis chunk size candidates for the autotune probe: batching
# trades per-iteration overhead (a vmapped masked step pays every phase
# for every lane) against straggler coupling (a chunk runs to its slowest
# lane); the crossover differs by backend, so the ladders do too.
_BATCH_CANDIDATES = {"cpu": (8, 16, 32)}
_BATCH_DEFAULT_CANDIDATES = (16, 32, 64, 128)


def _autotune_cache_path() -> str:
    root = os.environ.get("REPRO_BENCH_CACHE_DIR", "").strip() \
        or os.path.join(os.path.expanduser("~"), ".cache", "repro")
    return os.path.join(root, "autotune.json")


def _autotune_key() -> str:
    """Cache key: anything that shifts the batch-size crossover. The probe
    inherits the sharding setup, so device count is part of the key."""
    import jax
    return (f"{jax.default_backend()}|dev{len(sim._resolve_devices(None))}"
            f"|jax{jax.__version__}")


def _autotune_cache_load() -> dict:
    """Read the autotune cache, deleting it if corrupt (a crash mid-write
    cannot truncate it — writes are atomic — but tolerate hand edits)."""
    path = _autotune_cache_path()
    try:
        with open(path) as f:
            cache = json.load(f)
        if not isinstance(cache, dict):
            raise ValueError("autotune cache is not a JSON object")
        return cache
    except FileNotFoundError:
        return {}
    except (OSError, ValueError):
        print(f"# autotune cache {path} unreadable; deleting and re-probing")
        try:
            os.remove(path)
        except OSError:
            pass
        return {}


def _autotune_cache_store(key: str, value: int) -> None:
    path = _autotune_cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        cache = _autotune_cache_load()
        cache[key] = value
        camp.atomic_write_json(path, cache)
    except OSError as e:
        print(f"# autotune cache write failed ({e}); continuing uncached")


def _probe_batch_size(backend: str) -> int:
    """Timed probe: one tiny (8 mixes x 4 rates, 6-instance) LUT sweep per
    candidate chunk size; fastest wins. Results never depend on the value
    — only wall time and peak memory do."""
    cands = _BATCH_CANDIDATES.get(backend, _BATCH_DEFAULT_CANDIDATES)
    tiny = workloads.default_suite(n_instances=6)
    stacked = tiny.build_many([(mi, ri) for mi in range(8)
                               for ri in (0, 5, 9, 13)])
    t00 = time.time()
    best = None
    for b in cands:
        sim.run_batch(sim.MODE_LUT, stacked, params(), batch_size=b)  # warm
        t0 = time.perf_counter()
        np.asarray(sim.run_batch(sim.MODE_LUT, stacked, params(),
                                 batch_size=b).avg_exec_us)
        dt = time.perf_counter() - t0
        if best is None or dt < best[1]:
            best = (b, dt)
    print(f"# autotuned REPRO_BENCH_BATCH={best[0]} on {backend} "
          f"({len(cands)} candidates in {time.time()-t00:.0f}s)")
    return best[0]


@functools.lru_cache()
def batch_size() -> int:
    """Chunk size for every batched sweep in the benchmarks.

    `REPRO_BENCH_BATCH` wins when set; otherwise the on-disk autotune
    cache is consulted (keyed by backend + device count + jax version),
    and only on a miss does the timed probe run — saving ~10 s on every
    repeat benchmark run. Corrupt cache files are deleted and re-probed;
    stale entries (a different key) simply miss.
    """
    if os.environ.get("REPRO_BENCH_BATCH", "").strip():
        return _env_int("REPRO_BENCH_BATCH", 16)
    import jax
    key = _autotune_key()
    cached = _autotune_cache_load().get(key)
    if isinstance(cached, int) and cached > 0:
        print(f"# autotune cache hit: REPRO_BENCH_BATCH={cached} [{key}]")
        return cached
    best = _probe_batch_size(jax.default_backend())
    _autotune_cache_store(key, best)
    return best


# ---------------------------------------------------------------------------
# campaign routing: every benchmark grid goes through run_campaign
# ---------------------------------------------------------------------------
_CAMPAIGN_DIR = os.environ.get("REPRO_BENCH_CAMPAIGN_DIR", "").strip() or None
_SWEEP_STATS: List[Dict] = []


def set_campaign_dir(path: str | None) -> None:
    """Root directory for chunk checkpoints (`benchmarks.run --resume`).
    None disables checkpointing; sweeps still get watchdog + retry."""
    global _CAMPAIGN_DIR
    _CAMPAIGN_DIR = path


def campaign_dir() -> str | None:
    return _CAMPAIGN_DIR


def sweep(mode: int, wls, tree=None, rate_threshold=1e9, plan=None,
          label: str = "") -> sim.SimResult:
    """One crash-safe batched sweep: the campaign runner over `run_batch`.

    Chunk checkpoints land under `campaign_dir()` when set (so a killed
    benchmark run resumes bit-exactly); retry/timeout/shrink counters
    accumulate in `campaign_stats()` for the `--json` report.
    """
    out = camp.run_campaign(
        mode, wls, params(), tree=tree, rate_threshold=rate_threshold,
        plan=plan, batch_size=batch_size(),
        checkpoint_dir=campaign_dir(),
        watchdog_s=_env_opt_float("REPRO_BENCH_WATCHDOG_S"),
        step_budget=_env_opt_int("REPRO_BENCH_STEP_BUDGET"))
    _SWEEP_STATS.append({"label": label or f"mode {mode}", **out.stats})
    return out.result


def campaign_stats() -> Dict:
    """Aggregate campaign health over every sweep this process ran:
    retries, timeouts, OOM shrink events, stall trips, chunk reuse,
    per-chunk wall time, and lane occupancy (active while-loop trips over
    allocated ones — how much of each fixed-shape chunk's compute retired
    real events rather than spinning masked; length-aware packing exists
    to push this toward 1). Surfaced in `benchmarks.run --json`."""
    totals = {k: 0 for k in ("n_scenarios", "n_chunks", "chunks_reused",
                             "chunks_computed", "retries", "timeouts",
                             "oom_events", "shrinks", "stall_trips",
                             "lane_trips", "active_trips",
                             "retired_events")}
    walls: List[float] = []
    for s in _SWEEP_STATS:
        for k in totals:
            totals[k] += s[k]
        walls.extend(s["chunk_wall_s"])
    return {
        "n_sweeps": len(_SWEEP_STATS),
        **totals,
        "occupancy": (totals["active_trips"] / totals["lane_trips"]
                      if totals["lane_trips"] else None),
        "chunk_wall_s_max": max(walls) if walls else 0.0,
        "chunk_wall_s_mean": (sum(walls) / len(walls)) if walls else 0.0,
        "sweeps": _SWEEP_STATS,
    }


@functools.lru_cache()
def suite() -> workloads.WorkloadSuite:
    return workloads.default_suite(n_instances=N_INSTANCES)


@functools.lru_cache()
def params() -> sim.SimParams:
    return sim.make_params()


# the two oracle sweeps (MODE_ORACLE + MODE_ETF) are metric-independent —
# only the *labeling* of pending samples reads the metric — so they are
# cached per mode and shared across dataset(metric) calls instead of
# re-running the full 40x14 grid for every metric
_ORACLE_SWEEPS: Dict[int, sim.SimResult] = {}


def dataset(metric: str = "avg_exec_us") -> oracle.OracleDataset:
    # normalized through a single cache key: `dataset()` and
    # `dataset("avg_exec_us")` are the same dataset (a bare lru_cache
    # treats them as two entries and regenerates the whole grid)
    return _dataset(metric)


@functools.lru_cache()
def _dataset(metric: str) -> oracle.OracleDataset:
    t0 = time.time()

    def runner(m, stacked, p, bs):
        if m not in _ORACLE_SWEEPS:
            _ORACLE_SWEEPS[m] = sweep(m, stacked, label=f"oracle mode {m}")
        return _ORACLE_SWEEPS[m]

    ds = oracle.generate(suite(), params(), mix_indices=TRAIN_MIXES,
                         rate_indices=TRAIN_RATES, metric=metric,
                         batch_size=batch_size(), runner=runner)
    print(f"# oracle dataset[{metric}]: {len(ds)} samples "
          f"(S-frac {ds.labels.mean():.3f}) in {time.time()-t0:.0f}s")
    return ds


@functools.lru_cache()
def das_policy() -> das.DASPolicy:
    return das.fit_policy(dataset())


@functools.lru_cache()
def das_policy_auto(metric: str = "avg_exec_us") -> das.DASPolicy:
    """2 features chosen by greedy selection instead of the paper's pair."""
    from repro.core import classifier as clf
    ds = dataset(metric)
    tr, _ = oracle.train_test_split(ds)
    idx = np.random.RandomState(0).permutation(len(tr))[:6000]
    sel = clf.greedy_select(tr.features[idx], tr.labels[idx], k=2)
    return das.fit_policy(ds, feature_ids=sel)


@functools.lru_cache(maxsize=None)
def _cell_workload(mix_idx: int, rate_idx: int) -> workloads.FlatWorkload:
    return suite().build(mix_idx, rate_idx)


def eval_cell(mix_idx: int, rate_idx: int, mode: int,
              tree=None, rate_threshold: float = 1e9) -> sim.SimResult:
    return sim.run(mode, _cell_workload(mix_idx, rate_idx), params(),
                   tree=tree, rate_threshold=rate_threshold)


def eval_grid(cells: Sequence[Tuple[int, int]], mode: int,
              tree=None, rate_threshold: float = 1e9) -> List[sim.SimResult]:
    """One crash-safe batched sweep of `mode` over
    `[(mix_idx, rate_idx), ...]`.

    Returns per-cell `SimResult`s (same order as `cells`), computed by a
    single `sweep()` campaign chunked by `batch_size()` and sharded over
    `REPRO_BENCH_DEVICES`.
    """
    stacked = workloads.stack_workloads(
        [_cell_workload(mi, ri) for mi, ri in cells]
    )
    res = sweep(mode, stacked, tree=tree, rate_threshold=rate_threshold,
                label=f"grid mode {mode} ({len(cells)} cells)")
    out = [sim.result_at(res, k) for k in range(len(cells))]
    report_health(out, label=f"mode {mode}", cells=cells)
    return out


_STALL_REASONS = {sim.STALL_DEADLOCK: "deadlock",
                  sim.STALL_BUDGET: "step-budget"}


def report_health(results: Sequence[sim.SimResult], label: str = "",
                  cells: Sequence[Tuple[int, int]] | None = None) -> Dict:
    """Aggregate simulator health counters over a sweep and warn loudly,
    naming *which* scenarios misbehaved (index + (mix, rate) when known).

    A stalled cell (deadlock or iteration/step budget) or a dropped job
    (fault-injection deadline / retry exhaustion) silently skews
    averages; every grid sweep prints them."""
    def where(k):
        return (k, cells[k]) if cells is not None else (k,)

    stalled = [
        (*where(k), _STALL_REASONS.get(
            int(np.asarray(getattr(r, "stall_reason", 0))), "deadlock"))
        for k, r in enumerate(results) if bool(np.asarray(r.stalled))
        or int(np.asarray(getattr(r, "stall_reason", 0))) != sim.STALL_NONE
    ]
    dropped = [
        (*where(k), int(np.asarray(r.n_dropped_jobs)),
         int(np.asarray(r.n_dropped_tasks)))
        for k, r in enumerate(results)
        if int(np.asarray(r.n_dropped_jobs)) > 0
        or int(np.asarray(r.n_dropped_tasks)) > 0
    ]
    dropped_jobs = int(sum(int(np.asarray(r.n_dropped_jobs))
                           for r in results))
    dropped_tasks = int(sum(int(np.asarray(r.n_dropped_tasks))
                            for r in results))
    health = {"stalled_cells": len(stalled), "dropped_jobs": dropped_jobs,
              "dropped_tasks": dropped_tasks,
              "stalled_at": stalled, "dropped_at": dropped}
    if stalled:
        print(f"# WARNING [{label}]: {len(stalled)} stalled cell(s) — "
              "averages exclude unfinished work:")
        for entry in stalled[:8]:
            print(f"#   scenario {entry[0]}"
                  + (f" (mix, rate)={entry[1]}" if cells is not None else "")
                  + f" reason={entry[-1]}")
        if len(stalled) > 8:
            print(f"#   ... and {len(stalled) - 8} more")
    if dropped:
        print(f"# health [{label}]: {dropped_jobs} dropped job(s) / "
              f"{dropped_tasks} task(s) across {len(results)} cell(s):")
        for entry in dropped[:8]:
            print(f"#   scenario {entry[0]}"
                  + (f" (mix, rate)={entry[1]}" if cells is not None else "")
                  + f" jobs={entry[-2]} tasks={entry[-1]}")
        if len(dropped) > 8:
            print(f"#   ... and {len(dropped) - 8} more")
    return health


def eval_modes_grid(cells: Sequence[Tuple[int, int]],
                    with_fs: bool = False) -> Dict[str, List[sim.SimResult]]:
    """All scheduler modes over a cell grid, one batched sweep per mode.

    DAS = paper feature pair (rate, big-cluster availability);
    DAS-FS = the same depth-2 tree with the 2 features our feature-selection
    pass picks on these profiles (the paper's own methodology, IV-B)."""
    pol = das_policy()
    out = {
        "LUT": eval_grid(cells, sim.MODE_LUT),
        "ETF": eval_grid(cells, sim.MODE_ETF),
        "ETF-ideal": eval_grid(cells, sim.MODE_ETF_IDEAL),
        "DAS": eval_grid(cells, sim.MODE_DAS, tree=pol.tree),
    }
    if with_fs:
        out["DAS-FS"] = eval_grid(cells, sim.MODE_DAS,
                                  tree=das_policy_auto().tree)
    return out


def eval_all_modes(mix_idx: int, rate_idx: int,
                   with_fs: bool = False) -> Dict[str, sim.SimResult]:
    """Single-cell view of `eval_modes_grid` (kept for spot checks)."""
    grid = eval_modes_grid([(mix_idx, rate_idx)], with_fs=with_fs)
    return {k: v[0] for k, v in grid.items()}

"""Shared benchmark infrastructure: the trained DAS policy, the workload
suite and scheduler evaluation helpers. Results are cached in-process so
`benchmarks.run` trains the classifier once.

All (mix x rate) sweeps — oracle generation and the per-mode evaluation
grids — go through the sharded batched simulator path (`sim.run_batch`,
one fixed-shape-chunked, device-sharded sweep per mode instead of one
`sim.run` per cell).

Environment knobs:
  REPRO_BENCH_INSTANCES  frames per workload (default 60)
  REPRO_BENCH_FULL=0     opt OUT of the paper's full 40 mixes x 14 rates
                         grid back to the 10x8 training subset (the full
                         grid is the default since the sweep went
                         sharded + streaming)
  REPRO_BENCH_BATCH      scenario-axis chunk size for batched sweeps
                         (bounds peak memory, results are independent of
                         the value). Unset, it is autotuned once per
                         process by `batch_size()`: a small timed probe
                         over a backend-keyed candidate ladder (the
                         vmapped `lax.switch`/straggler crossover differs
                         between CPU and accelerators).
  REPRO_BENCH_DEVICES    number of devices `sim.run_batch` shards the
                         scenario axis over (default: all of
                         `jax.devices()`); per-scenario results are
                         independent of the device count
"""
from __future__ import annotations

import functools
import os
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import das, oracle, simulator as sim, workloads

def _env_int(name: str, default: int) -> int:
    """Positive-integer env knob; garbage or non-positive values are
    configuration errors, not something to silently coerce."""
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return default
    try:
        val = int(raw.strip())
    except ValueError:
        raise ValueError(
            f"{name}={raw!r} is not an integer (default {default})") from None
    if val <= 0:
        raise ValueError(f"{name}={val} must be a positive integer")
    return val


N_INSTANCES = _env_int("REPRO_BENCH_INSTANCES", 60)
# the paper's full 40 x 14 grid is the default; REPRO_BENCH_FULL=0 opts
# back out to the representative 10 x 8 training subset
FULL = os.environ.get("REPRO_BENCH_FULL", "1") != "0"

TRAIN_MIXES = list(range(40)) if FULL else [0, 1, 2, 3, 4, 5, 8, 12, 17, 22]
TRAIN_RATES = list(range(14)) if FULL else [0, 3, 5, 7, 9, 11, 12, 13]

# scenario-axis chunk size candidates for the autotune probe: batching
# trades per-iteration overhead (a vmapped masked step pays every phase
# for every lane) against straggler coupling (a chunk runs to its slowest
# lane); the crossover differs by backend, so the ladders do too.
_BATCH_CANDIDATES = {"cpu": (8, 16, 32)}
_BATCH_DEFAULT_CANDIDATES = (16, 32, 64, 128)


@functools.lru_cache()
def batch_size() -> int:
    """Chunk size for every `sim.run_batch` sweep in the benchmarks.

    `REPRO_BENCH_BATCH` wins when set; otherwise a small timed probe runs
    one tiny (8 mixes x 4 rates, 6-instance) LUT sweep per candidate chunk
    size and keeps the fastest. The probe inherits the real sharding setup
    (`REPRO_BENCH_DEVICES`), so it tunes what the sweeps actually run.
    Results never depend on the value — only wall time and peak memory do.
    """
    if os.environ.get("REPRO_BENCH_BATCH", "").strip():
        return _env_int("REPRO_BENCH_BATCH", 16)
    import jax
    backend = jax.default_backend()
    cands = _BATCH_CANDIDATES.get(backend, _BATCH_DEFAULT_CANDIDATES)
    tiny = workloads.default_suite(n_instances=6)
    stacked = tiny.build_many([(mi, ri) for mi in range(8)
                               for ri in (0, 5, 9, 13)])
    t00 = time.time()
    best = None
    for b in cands:
        sim.run_batch(sim.MODE_LUT, stacked, params(), batch_size=b)  # warm
        t0 = time.perf_counter()
        np.asarray(sim.run_batch(sim.MODE_LUT, stacked, params(),
                                 batch_size=b).avg_exec_us)
        dt = time.perf_counter() - t0
        if best is None or dt < best[1]:
            best = (b, dt)
    print(f"# autotuned REPRO_BENCH_BATCH={best[0]} on {backend} "
          f"({len(cands)} candidates in {time.time()-t00:.0f}s)")
    return best[0]


@functools.lru_cache()
def suite() -> workloads.WorkloadSuite:
    return workloads.default_suite(n_instances=N_INSTANCES)


@functools.lru_cache()
def params() -> sim.SimParams:
    return sim.make_params()


@functools.lru_cache()
def dataset(metric: str = "avg_exec_us") -> oracle.OracleDataset:
    t0 = time.time()
    ds = oracle.generate(suite(), params(), mix_indices=TRAIN_MIXES,
                         rate_indices=TRAIN_RATES, metric=metric,
                         batch_size=batch_size())
    print(f"# oracle dataset[{metric}]: {len(ds)} samples "
          f"(S-frac {ds.labels.mean():.3f}) in {time.time()-t0:.0f}s")
    return ds


@functools.lru_cache()
def das_policy() -> das.DASPolicy:
    return das.fit_policy(dataset())


@functools.lru_cache()
def das_policy_auto(metric: str = "avg_exec_us") -> das.DASPolicy:
    """2 features chosen by greedy selection instead of the paper's pair."""
    from repro.core import classifier as clf
    ds = dataset(metric)
    tr, _ = oracle.train_test_split(ds)
    idx = np.random.RandomState(0).permutation(len(tr))[:6000]
    sel = clf.greedy_select(tr.features[idx], tr.labels[idx], k=2)
    return das.fit_policy(ds, feature_ids=sel)


@functools.lru_cache(maxsize=None)
def _cell_workload(mix_idx: int, rate_idx: int) -> workloads.FlatWorkload:
    return suite().build(mix_idx, rate_idx)


def eval_cell(mix_idx: int, rate_idx: int, mode: int,
              tree=None, rate_threshold: float = 1e9) -> sim.SimResult:
    return sim.run(mode, _cell_workload(mix_idx, rate_idx), params(),
                   tree=tree, rate_threshold=rate_threshold)


def eval_grid(cells: Sequence[Tuple[int, int]], mode: int,
              tree=None, rate_threshold: float = 1e9) -> List[sim.SimResult]:
    """One batched sweep of `mode` over `[(mix_idx, rate_idx), ...]`.

    Returns per-cell `SimResult`s (same order as `cells`), computed by a
    single `run_batch` call chunked by `batch_size()` and sharded over
    `REPRO_BENCH_DEVICES`.
    """
    stacked = workloads.stack_workloads(
        [_cell_workload(mi, ri) for mi, ri in cells]
    )
    res = sim.run_batch(mode, stacked, params(), tree=tree,
                        rate_threshold=rate_threshold,
                        batch_size=batch_size())
    out = [sim.result_at(res, k) for k in range(len(cells))]
    report_health(out, label=f"mode {mode}", cells=cells)
    return out


def report_health(results: Sequence[sim.SimResult], label: str = "",
                  cells: Sequence[Tuple[int, int]] | None = None) -> Dict:
    """Aggregate simulator health counters over a sweep and warn loudly.

    A stalled cell (simulator hit its iteration guard before draining the
    workload) or a dropped job (fault-injection deadline / retry
    exhaustion) silently skews averages; every grid sweep prints them."""
    stalled = [k for k, r in enumerate(results) if bool(np.asarray(r.stalled))]
    dropped_jobs = int(sum(int(np.asarray(r.n_dropped_jobs))
                           for r in results))
    dropped_tasks = int(sum(int(np.asarray(r.n_dropped_tasks))
                            for r in results))
    health = {"stalled_cells": len(stalled), "dropped_jobs": dropped_jobs,
              "dropped_tasks": dropped_tasks}
    if stalled:
        where = [cells[k] for k in stalled] if cells is not None else stalled
        print(f"# WARNING [{label}]: {len(stalled)} stalled cell(s) at "
              f"{where[:8]}{'...' if len(where) > 8 else ''} — averages "
              "exclude unfinished work")
    if dropped_jobs:
        print(f"# health [{label}]: {dropped_jobs} dropped job(s) / "
              f"{dropped_tasks} task(s) across {len(results)} cell(s)")
    return health


def eval_modes_grid(cells: Sequence[Tuple[int, int]],
                    with_fs: bool = False) -> Dict[str, List[sim.SimResult]]:
    """All scheduler modes over a cell grid, one batched sweep per mode.

    DAS = paper feature pair (rate, big-cluster availability);
    DAS-FS = the same depth-2 tree with the 2 features our feature-selection
    pass picks on these profiles (the paper's own methodology, IV-B)."""
    pol = das_policy()
    out = {
        "LUT": eval_grid(cells, sim.MODE_LUT),
        "ETF": eval_grid(cells, sim.MODE_ETF),
        "ETF-ideal": eval_grid(cells, sim.MODE_ETF_IDEAL),
        "DAS": eval_grid(cells, sim.MODE_DAS, tree=pol.tree),
    }
    if with_fs:
        out["DAS-FS"] = eval_grid(cells, sim.MODE_DAS,
                                  tree=das_policy_auto().tree)
    return out


def eval_all_modes(mix_idx: int, rate_idx: int,
                   with_fs: bool = False) -> Dict[str, sim.SimResult]:
    """Single-cell view of `eval_modes_grid` (kept for spot checks)."""
    grid = eval_modes_grid([(mix_idx, rate_idx)], with_fs=with_fs)
    return {k: v[0] for k, v in grid.items()}

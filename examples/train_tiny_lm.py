"""End-to-end training driver: train a reduced-config model for a few
hundred steps on CPU with checkpointing, failure injection and resume —
the full production loop at toy scale.

    PYTHONPATH=src python examples/train_tiny_lm.py [--arch yi-34b]
        [--steps 300] [--compress] [--fail-at 150]
"""
import argparse
import shutil

import jax

from repro import configs
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.train import optimizer as optim
from repro.train import trainer as tr

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="yi-34b", choices=configs.ARCH_IDS)
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--compress", action="store_true")
ap.add_argument("--fail-at", type=int, default=None)
ap.add_argument("--ckpt-dir", default="/tmp/repro_example_train")
ap.add_argument("--fresh", action="store_true")
args = ap.parse_args()

if args.fresh:
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

cfg = configs.get_smoke_config(args.arch, n_layers=4, d_model=128,
                               vocab=512)
mesh = jax.make_mesh((1, 1), ("data", "model"))
data = Prefetcher(SyntheticLM(vocab=cfg.vocab, batch=8, seq_len=128,
                              n_codebooks=cfg.n_codebooks))
tcfg = tr.TrainerConfig(
    total_steps=args.steps, ckpt_every=max(args.steps // 5, 10),
    ckpt_dir=args.ckpt_dir, log_every=25,
    grad_compression="int8" if args.compress else None)
ocfg = optim.AdamWConfig(lr_peak=3e-3, warmup_steps=args.steps // 10,
                         total_steps=args.steps)

t = tr.Trainer(tcfg, cfg, ocfg, mesh, data)
if args.fail_at:
    t.inject_failure_at = args.fail_at
out = t.fit(resume=True)
print(f"\nfinished: step {out['step']}, restarts {out['restarts']}, "
      f"loss {out['metrics'][0]['loss']:.3f} -> "
      f"{out['metrics'][-1]['loss']:.3f}")
data.close()

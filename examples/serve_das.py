"""Serve a model with DAS-dispatched continuous batching: train the
dispatch classifier, then sweep request rates against LUT/ETF baselines.

    PYTHONPATH=src python examples/serve_das.py [--arch minicpm3-4b]
"""
import argparse

from repro import configs
from repro.serve import costmodel as cm
from repro.serve import dispatch as dsp
from repro.serve import engine as eng

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="minicpm3-4b", choices=configs.ARCH_IDS)
args = ap.parse_args()

cfg = eng.EngineConfig(n_replicas=4, max_batch=16)
spec = cm.ReplicaSpec("v5e-8", n_chips=8)
mc = cm.ModelCost.from_config(configs.get_config(args.arch))

scenarios = [(r, 120, s) for r in (2, 10, 40, 120, 300) for s in (0, 1)]
das = dsp.train_das_dispatcher(scenarios, cfg, spec, mc)
print(f"DAS dispatcher trained: acc {das.train_accuracy:.3f}, "
      f"slow-label fraction {das.label_slow_frac:.3f}\n")

print(f"{'req/s':>6} | {'LUT ms':>8} {'ETF ms':>8} {'DAS ms':>8} | slow%")
for rate in (5, 20, 60, 150, 350):
    row = {}
    for name, d in (("LUT", dsp.LUTDispatcher(4)),
                    ("ETF", dsp.ETFDispatcher()),
                    ("DAS", dsp.DASDispatcher(das.tree, 4))):
        reqs = eng.poisson_requests(rate, 150, seed=3)
        row[name] = eng.run_engine(reqs, d, cfg, spec, mc)
    sf = row["DAS"].dispatch_slow / max(
        row["DAS"].dispatch_fast + row["DAS"].dispatch_slow, 1)
    print(f"{rate:6.0f} | {row['LUT'].mean_latency_s*1e3:8.1f} "
          f"{row['ETF'].mean_latency_s*1e3:8.1f} "
          f"{row['DAS'].mean_latency_s*1e3:8.1f} | {sf:5.0%}")

"""Quickstart: train the DAS preselection classifier and beat both
underlying schedulers on a congested workload.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import das, simulator as sim, workloads

# 1. build a workload suite (mixes of the five streaming applications)
suite = workloads.default_suite(n_instances=40)
params = sim.make_params()

# 2. train DAS: two-execution oracle -> depth-2 decision tree on the
#    paper's two features (input data rate, big-cluster availability)
policy = das.train_das_policy = das.train_das(
    suite, params,
    mix_indices=[0, 1, 3, 4, 5],      # tx/rx/temporal/app1/uniform mixes
    rate_indices=[0, 5, 9, 12, 13],
)
print(f"classifier: train acc {policy.train_accuracy:.3f}, "
      f"test acc {policy.test_accuracy:.3f} on {policy.n_train} samples")

# 3. evaluate on a congested wifi-rx workload
wl = suite.build(mix_idx=1, rate_idx=11)
for name, mode, kw in [
    ("LUT (fast)", sim.MODE_LUT, {}),
    ("ETF (slow)", sim.MODE_ETF, {}),
    ("DAS", sim.MODE_DAS, {"tree": policy.tree}),
]:
    r = sim.run(mode, wl, params, **kw)
    frac = int(r.n_slow) / max(int(r.n_decisions), 1)
    print(f"{name:12s} avg exec {float(r.avg_exec_us):7.2f} us | "
          f"EDP {float(r.edp):9.0f} | slow-scheduler use {frac:4.0%}")

"""End-to-end DSSoC study: sweep all 14 data rates on a chosen application
mix and print the four-scheduler comparison (a Fig. 2 panel).

    PYTHONPATH=src python examples/soc_simulation.py [mix_idx]
"""
import sys

from repro.core import das, simulator as sim, workloads

mix = int(sys.argv[1]) if len(sys.argv) > 1 else 5  # uniform five-app mix
suite = workloads.default_suite(n_instances=60)
params = sim.make_params()

policy = das.train_das(suite, params, mix_indices=[0, 1, 3, 4, 5],
                       rate_indices=[0, 5, 9, 12, 13])

print(f"mix {mix}: ratios {suite.mixes[mix].round(2)}")
print(f"{'rate Mbps':>10} | {'LUT':>8} {'ETF':>8} {'ETF-ideal':>9} "
      f"{'DAS':>8} | {'DAS slow%':>9}")
for ri in range(len(suite.rates)):
    wl = suite.build(mix, ri)
    r = {}
    r["LUT"] = sim.run(sim.MODE_LUT, wl, params)
    r["ETF"] = sim.run(sim.MODE_ETF, wl, params)
    r["IDE"] = sim.run(sim.MODE_ETF_IDEAL, wl, params)
    r["DAS"] = sim.run(sim.MODE_DAS, wl, params, tree=policy.tree)
    sf = int(r["DAS"].n_slow) / max(int(r["DAS"].n_decisions), 1)
    print(f"{float(suite.rates[ri]):10.1f} | "
          f"{float(r['LUT'].avg_exec_us):8.2f} "
          f"{float(r['ETF'].avg_exec_us):8.2f} "
          f"{float(r['IDE'].avg_exec_us):9.2f} "
          f"{float(r['DAS'].avg_exec_us):8.2f} | {sf:9.0%}")

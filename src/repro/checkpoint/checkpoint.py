"""Fault-tolerant checkpointing.

Layout:  <dir>/step_<N>/
             manifest.json     (treedef, shapes, dtypes, step, extra meta)
             arrays.npz        (flat leaves, keyed "leaf_<i>")
         <dir>/LATEST          (atomic pointer file)

Properties:
  * atomic: written to a tmp dir, fsync'd, then os.replace'd; LATEST is
    swapped last, so a crash mid-write never corrupts the restore path.
  * async: `save_async` runs in a daemon thread (the train loop keeps going;
    `wait()` joins before the next save).
  * elastic: restore is mesh-agnostic — arrays are loaded host-side and
    `jax.device_put` against whatever sharding the *new* mesh prescribes, so
    a job restarted with a different device count resumes cleanly.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree, step: int, meta: Optional[dict] = None) -> str:
    leaves, treedef = _flatten(tree)
    step_dir = os.path.join(path, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(os.path.join(tmp_dir, "arrays.npz"), **arrays)
    manifest = {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "step": step,
        "shapes": [list(a.shape) for a in arrays.values()],
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "meta": meta or {},
    }
    mpath = os.path.join(tmp_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)
    latest_tmp = os.path.join(path, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(step_dir))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(path, "LATEST"))
    return step_dir


def latest_step(path: str) -> Optional[int]:
    lp = os.path.join(path, "LATEST")
    if not os.path.exists(lp):
        return None
    with open(lp) as f:
        name = f.read().strip()
    if not name.startswith("step_"):
        return None
    d = os.path.join(path, name)
    if not os.path.exists(os.path.join(d, "manifest.json")):
        return None
    return int(name[5:])


def restore(path: str, like, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int, dict]:
    """Restore into the structure of `like` (a pytree or abstract pytree).
    If `shardings` (matching pytree of NamedShardings) is given, leaves are
    device_put with them — this is the elastic-remesh path."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    step_dir = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(step_dir, "arrays.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    _, treedef = _flatten(like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, step, manifest["meta"]


class AsyncCheckpointer:
    """Serializes saves on a daemon thread; overlaps I/O with training."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(path, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, tree, step: int, meta: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot on host

        def run():
            try:
                save(self.path, host_tree, step, meta)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()


def prune_old(path: str, keep: int = 3):
    if not os.path.isdir(path):
        return
    steps = sorted(
        int(d[5:]) for d in os.listdir(path)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(path, f"step_{s:08d}"), ignore_errors=True)

"""Primitive layers: pure init/apply functions over pytree params.

Conventions:
  * params are nested dicts of jnp arrays, fp32 at rest (`param_dtype`),
    cast to the compute dtype inside `apply`.
  * every init takes a `jax.random.PRNGKey` and returns a dict.
  * shapes use named comments: B batch, S seq, D d_model, H heads, K kv
    heads, Dh head dim, F d_ff, V vocab, E experts.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, std, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def dense_init(key, d_in: int, d_out, std: Optional[float] = None,
               dtype=jnp.float32):
    """Weight of shape (d_in, *d_out) with fan-in scaled init."""
    if isinstance(d_out, int):
        d_out = (d_out,)
    std = std if std is not None else 1.0 / np.sqrt(d_in)
    return truncated_normal(key, (d_in, *d_out), std, dtype)


def linear(x, w, b=None):
    """x [..., d_in] @ w [d_in, *rest] -> [..., *rest]."""
    out_axes = w.ndim - 1
    y = jax.lax.dot_general(
        x, w.astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
    )
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def rms_norm(x, scale, eps: float = 1e-6, zero_centered: bool = False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    if zero_centered:
        s = s + 1.0
    return (y * s).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(d_rot: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32)
                            / d_rot))


def apply_rope(x, positions, theta: float = 10000.0,
               rotary_pct: float = 1.0):
    """x [B, S, H, Dh]; positions [B, S] (int). Rotates the leading
    `rotary_pct` fraction of Dh, half-split convention."""
    d = x.shape[-1]
    d_rot = int(d * rotary_pct) // 2 * 2
    if d_rot == 0:
        return x
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    freqs = rope_freqs(d_rot, theta)                       # [d_rot/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, d_rot/2]
    cos = jnp.cos(ang)[..., None, :]                        # [B, S, 1, ...]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_init(key, d_model: int, d_ff: int, kind: str):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, d_model, d_ff),
            "w_up": dense_init(k2, d_model, d_ff),
            "w_down": dense_init(k3, d_ff, d_model),
        }
    if kind == "gelu":
        return {
            "w_up": dense_init(k1, d_model, d_ff),
            "b_up": jnp.zeros((d_ff,)),
            "w_down": dense_init(k2, d_ff, d_model),
            "b_down": jnp.zeros((d_model,)),
        }
    raise ValueError(kind)


def mlp_apply(p, x, kind: str):
    if kind == "swiglu":
        return linear(jax.nn.silu(linear(x, p["w_gate"]))
                      * linear(x, p["w_up"]), p["w_down"])
    if kind == "geglu":
        return linear(jax.nn.gelu(linear(x, p["w_gate"]), approximate=True)
                      * linear(x, p["w_up"]), p["w_down"])
    if kind == "gelu":
        h = jax.nn.gelu(linear(x, p["w_up"], p["b_up"]), approximate=True)
        return linear(h, p["w_down"], p["b_down"])
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# causal depthwise conv1d (mamba / griffin style, cached for decode)
# ---------------------------------------------------------------------------
def conv1d_init(key, width: int, channels: int):
    return {
        "w": truncated_normal(key, (width, channels), 1.0 / np.sqrt(width)),
        "b": jnp.zeros((channels,)),
    }


def conv1d_apply(p, x):
    """Causal depthwise conv. x [B, S, C] -> [B, S, C]."""
    w = p["w"].astype(x.dtype)                    # [W, C]
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):                        # small fixed width: unroll
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return out + p["b"].astype(x.dtype)


def conv1d_step(p, x_t, window):
    """Single decode step. x_t [B, C]; window [B, W-1, C] (trailing inputs).
    Returns (y_t [B, C], new_window)."""
    w = p["w"].astype(x_t.dtype)
    width = w.shape[0]
    full = jnp.concatenate([window, x_t[:, None, :]], axis=1)  # [B, W, C]
    y = jnp.einsum("bwc,wc->bc", full, w) + p["b"].astype(x_t.dtype)
    return y, full[:, -(width - 1):, :] if width > 1 else window

"""RG-LRU recurrent block (Griffin / RecurrentGemma).

y = W_out( RG_LRU(conv1d(W_x x)) * gelu(W_gate x) )

RG-LRU recurrence (per channel):
    r_t = sigmoid(w_r x_t + b_r)          recurrence gate
    i_t = sigmoid(w_i x_t + b_i)          input gate
    a_t = exp(-c * softplus(L) * r_t)     log-space decay, L learnable
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses an associative scan over the sequence; decode carries
h (and the conv window) in `RGLRUState`.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import modules as nn


class RGLRUState(NamedTuple):
    h: jax.Array         # [B, R] recurrent state
    conv: jax.Array      # [B, W-1, R] conv window

    @staticmethod
    def init(batch, d_rnn, conv_width, dtype=jnp.float32):
        return RGLRUState(
            jnp.zeros((batch, d_rnn), dtype),
            jnp.zeros((batch, conv_width - 1, d_rnn), dtype),
        )


def rglru_init(key, cfg):
    rc = cfg.rglru
    d = cfg.d_model
    r = rc.d_rnn or d
    ks = jax.random.split(key, 7)
    # Lambda init so that a ~ U(0.9, 0.999)^c-ish (Griffin appendix)
    u = jax.random.uniform(ks[0], (r,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / rc.c))  # softplus^-1(-ln u / c)
    return {
        "w_x": nn.dense_init(ks[1], d, r),
        "w_gate": nn.dense_init(ks[2], d, r),
        "conv": nn.conv1d_init(ks[3], rc.conv_width, r),
        "w_r": nn.dense_init(ks[4], r, r, std=1.0 / np.sqrt(r)),
        "b_r": jnp.zeros((r,)),
        "w_i": nn.dense_init(ks[5], r, r, std=1.0 / np.sqrt(r)),
        "b_i": jnp.zeros((r,)),
        "lam": lam,
        "w_out": nn.dense_init(ks[6], r, d),
    }


def _gates(p, cfg, u):
    """u [B,S,R] (post-conv) -> (a, bx) with h_t = a h_{t-1} + bx."""
    rc = cfg.rglru
    r = jax.nn.sigmoid(nn.linear(u, p["w_r"], p["b_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid(nn.linear(u, p["w_i"], p["b_i"]).astype(jnp.float32))
    log_a = -rc.c * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = beta * (i * u.astype(jnp.float32))
    return a, bx


def _scan(a, bx, h0=None):
    """Linear recurrence via associative scan along axis 1 (fp32)."""
    if h0 is not None:
        # fold the carry into the first step
        bx = bx.at[:, 0].add(a[:, 0] * h0)
    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(comb, (a, bx), axis=1)
    return h


def rglru_apply(p, cfg, x, state: Optional[RGLRUState] = None):
    """x [B,S,D] -> (y [B,S,D], new_state)."""
    rc = cfg.rglru
    gate = jax.nn.gelu(nn.linear(x, p["w_gate"]), approximate=True)
    u = nn.linear(x, p["w_x"])
    if state is None:
        u = nn.conv1d_apply(p["conv"], u)
        a, bx = _gates(p, cfg, u)
        if cfg.use_pallas:
            from repro.kernels.rg_lru import ops as rg_ops
            h = rg_ops.rg_lru_scan(a, bx)
        else:
            h = _scan(a, bx)
        new_state = None
    else:
        if x.shape[1] == 1:
            ut, conv_w = nn.conv1d_step(p["conv"], u[:, 0], state.conv)
            a, bx = _gates(p, cfg, ut[:, None, :])
            h = a * state.h[:, None, :].astype(jnp.float32) + bx
            new_state = RGLRUState(h[:, -1].astype(state.h.dtype), conv_w)
        else:  # chunked prefill with carry
            full = jnp.concatenate(
                [state.conv.astype(u.dtype), u], axis=1)
            u = nn.conv1d_apply(p["conv"], full)[:, state.conv.shape[1]:]
            a, bx = _gates(p, cfg, u)
            h = _scan(a, bx, h0=state.h.astype(jnp.float32))
            new_state = RGLRUState(
                h[:, -1].astype(state.h.dtype),
                full[:, -(rc.conv_width - 1):, :].astype(state.conv.dtype))
    y = nn.linear(h.astype(x.dtype) * gate, p["w_out"])
    return y, new_state

"""Block assembly: pre-norm residual blocks, scan-over-layer-groups with
rematerialization, heterogeneous block patterns.

The layer pattern (cfg.pattern) repeats with period P; parameters are stored
as a list of `P` stacked pytrees (leading axis = number of repetitions), so
`jax.lax.scan` runs over repetition groups while each group applies its P
heterogeneous blocks. Leading non-repeating layers (e.g. DeepSeek's dense
layer 0) live in `prologue`.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, mla, moe, rglru, ssd
from repro.models import modules as nn
from repro.parallel import sharding as shd


# -------------------------- per-block init/apply ---------------------------
def block_init(key, cfg, kind: str, layer_idx: int):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,))}
    if kind in ("attn", "local"):
        if cfg.attn_impl == "mla" and kind == "attn":
            p["attn"] = mla.mla_init(ks[0], cfg)
        else:
            p["attn"] = attention.attn_init(ks[0], cfg)
    elif kind == "rglru":
        p["attn"] = rglru.rglru_init(ks[0], cfg)
    elif kind == "ssd":
        p["attn"] = ssd.ssd_init(ks[0], cfg)
        return p                       # SSD block has no separate MLP
    else:
        raise ValueError(kind)
    p["ln2"] = jnp.ones((cfg.d_model,))
    if cfg.mlp_type == "moe" and layer_idx >= cfg.moe.first_k_dense:
        p["mlp"] = moe.moe_init(ks[1], cfg)   # has "router" => MoE block
    elif cfg.mlp_type != "none":
        kind_mlp = "swiglu" if cfg.mlp_type == "moe" else cfg.mlp_type
        d_ff = cfg.d_ff
        p["mlp"] = nn.mlp_init(ks[1], cfg.d_model, d_ff, kind_mlp)
    return p


def block_apply(p, cfg, kind: str, x, positions, prefix_len=None,
                cache=None, cache_pos=None, kv_valid=None):
    """One residual block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    h = nn.rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn" and cfg.attn_impl == "mla":
        y, new_cache = mla.mla_apply(p["attn"], cfg, h, positions,
                                     cache=cache, cache_pos=cache_pos,
                                     kv_valid=kv_valid)
    elif kind in ("attn", "local"):
        window = cfg.window if kind == "local" else 0
        y, new_cache = attention.attn_apply(
            p["attn"], cfg, h, positions, prefix_len=prefix_len,
            window=window, cache=cache, cache_pos=cache_pos,
            kv_valid=kv_valid)
    elif kind == "rglru":
        y, new_cache = rglru.rglru_apply(p["attn"], cfg, h, state=cache)
    elif kind == "ssd":
        y, new_cache = ssd.ssd_apply(p["attn"], cfg, h, state=cache)
        return (shd.constrain(x + y.astype(x.dtype),
                              ("batch", "seq", None)), new_cache, aux)
    else:
        raise ValueError(kind)
    x = shd.constrain(x + y.astype(x.dtype), ("batch", "seq", None))
    if "mlp" in p:
        h2 = nn.rms_norm(x, p["ln2"], cfg.norm_eps)
        if "router" in p["mlp"]:
            y2, aux = moe.moe_apply(p["mlp"], cfg, h2)
        else:
            kind_mlp = "swiglu" if cfg.mlp_type == "moe" else cfg.mlp_type
            y2 = nn.mlp_apply(p["mlp"], h2, kind_mlp)
        x = shd.constrain(x + y2.astype(x.dtype), ("batch", "seq", None))
    return x, new_cache, aux


# ----------------------------- stack init ----------------------------------
def stack_layout(cfg) -> Tuple[List[str], List[str], int]:
    """Returns (prologue_kinds, period_kinds, n_groups)."""
    pat = list(cfg.pattern_full)
    n_pro = cfg.moe.first_k_dense if (cfg.mlp_type == "moe"
                                      and cfg.moe is not None) else 0
    period = len(cfg.pattern)
    body = pat[n_pro:]
    n_groups = len(body) // period
    rem = len(body) - n_groups * period
    # any ragged tail joins the prologue (kept unscanned)
    prologue = pat[:n_pro] + (body[n_groups * period:] if rem else [])
    return prologue, list(cfg.pattern), n_groups


def stack_init(key, cfg):
    prologue, period, n_groups = stack_layout(cfg)
    keys = jax.random.split(key, len(prologue) + n_groups * len(period) + 1)
    ki = 0
    pro_params = []
    for i, kind in enumerate(prologue):
        pro_params.append(block_init(keys[ki], cfg, kind, layer_idx=i))
        ki += 1
    base = len(prologue)
    groups = []
    for slot, kind in enumerate(period):
        reps = []
        for g in range(n_groups):
            layer_idx = base + g * len(period) + slot
            reps.append(block_init(keys[ki], cfg, kind, layer_idx=layer_idx))
            ki += 1
        groups.append(jax.tree.map(lambda *a: jnp.stack(a), *reps)
                      if n_groups > 0 else None)
    return {"prologue": pro_params, "groups": groups}


# ----------------------------- stack apply ---------------------------------
def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint(fn, policy=policy)


def stack_apply(params, cfg, x, positions, prefix_len=None,
                caches=None, cache_pos=None, kv_valid=None):
    """Apply all blocks. `caches` is None (training) or a dict:
       {"prologue": [cache,...], "groups": [stacked cache,...]}.
    Returns (x, new_caches, total_aux)."""
    prologue, period, n_groups = stack_layout(cfg)
    aux_total = jnp.float32(0.0)
    new_caches: Dict[str, Any] = {"prologue": [], "groups": []}

    for i, kind in enumerate(prologue):
        c = caches["prologue"][i] if caches is not None else None
        fn = _remat(cfg, lambda p, xx, cc, kind=kind: block_apply(
            p, cfg, kind, xx, positions, prefix_len, cc, cache_pos,
            kv_valid))
        x, nc, aux = fn(params["prologue"][i], x, c)
        new_caches["prologue"].append(nc)
        aux_total = aux_total + aux

    if n_groups > 0:
        def group_body(carry, scanned):
            xx, aux_acc = carry
            gparams, gcaches = scanned
            ncs = []
            for slot, kind in enumerate(period):
                c = gcaches[slot] if gcaches is not None else None
                fn = _remat(cfg, lambda p, h, cc, kind=kind: block_apply(
                    p, cfg, kind, h, positions, prefix_len, cc, cache_pos,
                    kv_valid))
                xx, nc, aux = fn(gparams[slot], xx, c)
                ncs.append(nc)
                aux_acc = aux_acc + aux
            return (xx, aux_acc), tuple(ncs)

        gcaches = caches["groups"] if caches is not None else None
        if gcaches is None:
            gcaches_b = None
            (x, aux_total), stacked_nc = jax.lax.scan(
                lambda c, gp: group_body(c, (gp, None)),
                (x, aux_total), tuple(params["groups"]))
        else:
            (x, aux_total), stacked_nc = jax.lax.scan(
                group_body, (x, aux_total),
                (tuple(params["groups"]), tuple(gcaches)))
        new_caches["groups"] = list(stacked_nc)

    return x, (new_caches if caches is not None else None), aux_total


# ----------------------------- cache init ----------------------------------
def stack_cache_init(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Build the cache pytree matching stack_apply's expectations."""
    prologue, period, n_groups = stack_layout(cfg)

    def one(kind):
        if kind == "attn" and cfg.attn_impl == "mla":
            return mla.MLACache.init(batch, max_len, cfg.mla.kv_lora_rank,
                                     cfg.mla.qk_rope_head_dim, dtype)
        if kind == "attn":
            return attention.KVCache.init(batch, max_len, cfg.n_kv_heads,
                                          cfg.d_head, dtype)
        if kind == "local":
            if cfg.window and cfg.window < max_len:
                return attention.WindowKVCache.init(
                    batch, cfg.window, cfg.n_kv_heads, cfg.d_head, dtype)
            return attention.KVCache.init(batch, max_len, cfg.n_kv_heads,
                                          cfg.d_head, dtype)
        if kind == "rglru":
            r = cfg.rglru.d_rnn or cfg.d_model
            return rglru.RGLRUState.init(batch, r, cfg.rglru.conv_width)
        if kind == "ssd":
            _, n_heads = ssd.ssd_dims(cfg)
            return ssd.SSDState.init(batch, n_heads, cfg.ssd.d_state,
                                     cfg.ssd.head_dim, cfg.ssd.conv_width,
                                     cfg.ssd.n_groups)
        raise ValueError(kind)

    caches = {"prologue": [one(k) for k in prologue], "groups": []}
    for kind in period:
        if n_groups > 0:
            c = one(kind)
            caches["groups"].append(
                jax.tree.map(lambda a: jnp.broadcast_to(
                    a[None], (n_groups,) + a.shape), c))
    return caches

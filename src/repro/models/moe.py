"""Mixture-of-experts MLP with sort-based capacity dispatch.

Router: softmax top-k (+ optional always-on shared experts, DeepSeekMoE
style). Dispatch: tokens are sorted by destination expert and packed into an
[E, C, D] buffer (C = capacity), the expert SwiGLU runs as a batched einsum
over the expert axis (shardable along the mesh "model"/expert axis), and
outputs scatter back weighted by the router gate. Overflowing tokens beyond
capacity are dropped (standard Switch/GShard semantics; the aux load-balance
loss keeps the drop rate low).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import modules as nn


def moe_init(key, cfg):
    mc = cfg.moe
    d = cfg.d_model
    f = mc.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": nn.dense_init(ks[0], d, mc.n_experts, std=0.02),
        "w_gate": _expert_stack(ks[1], mc.n_experts, d, f),
        "w_up": _expert_stack(ks[2], mc.n_experts, d, f),
        "w_down": _expert_stack(ks[3], mc.n_experts, f, d),
    }
    if mc.n_shared:
        p["shared"] = nn.mlp_init(ks[4], d, f * mc.n_shared, "swiglu")
    return p


def _expert_stack(key, e, d_in, d_out):
    return nn.truncated_normal(key, (e, d_in, d_out), 1.0 / np.sqrt(d_in))


def router_topk(logits, k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """logits [..., E] -> (weights [...,k], idx [...,k], aux_loss).
    Leading dims may be (G, Tl) so the top_k stays shard-local."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    E = logits.shape[-1]
    me = probs.reshape(-1, E).mean(0)                      # mean prob per e
    ce = jnp.zeros((E,)).at[idx.reshape(-1)].add(1.0) / idx.size
    aux = E * jnp.sum(me * ce)
    return w, idx, aux


def moe_apply(p, cfg, x):
    """x [B, S, D] -> (y, aux_loss).

    Dispatch runs within `G = moe.n_dispatch_shards` independent token
    groups (G<=1: one global sort). With G aligned to the DP sharding every
    sort/cumsum/scatter is shard-local, so the only cross-device movement
    is the (token-shard -> expert-shard) buffer exchange — the EP
    all-to-all — instead of a global multi-collective sort (§Perf)."""
    mc = cfg.moe
    B, S, D = x.shape
    T = B * S
    G = max(mc.n_dispatch_shards, 1)
    if B % G != 0:
        G = 1
    Tl = T // G
    xt = x.reshape(G, Tl, D)
    # grouped router: top_k over [G, Tl, E] keeps the selection shard-local
    # (a flat [T, E] top_k was observed to full-gather the probs)
    w, idx, aux = router_topk(nn.linear(xt, p["router"]), mc.top_k)

    E = mc.n_experts
    C = int(np.ceil(Tl * mc.top_k / E * mc.capacity_factor))
    C = max(C, 8)

    K = mc.top_k
    flat_e = idx.reshape(G, Tl * K)                        # [G, Tl*k]
    flat_w = w.reshape(G, Tl * K).astype(x.dtype)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tl), K)[None], (G, Tl * K))

    order = jnp.argsort(flat_e, axis=1)                    # per-group sort
    se = jnp.take_along_axis(flat_e, order, 1)
    stok = jnp.take_along_axis(flat_tok, order, 1)
    sw = jnp.take_along_axis(flat_w, order, 1)
    # position within expert segment (per group)
    seg_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E), side="left"))(se)
    pos = (jnp.arange(Tl * K)[None]
           - jnp.take_along_axis(seg_start, se, 1))
    keep = pos < C
    dest = se * C + jnp.where(keep, pos, 0)

    gathered = jnp.take_along_axis(
        xt, stok[..., None], 1)                            # [G, Tl*k, D]
    buf = jnp.zeros((G, E * C, D), x.dtype)
    buf = jax.vmap(lambda b, d, v: b.at[d].add(v))(
        buf, dest, jnp.where(keep[..., None], gathered, 0))
    h = buf.reshape(G, E, C, D)
    if G > 1:
        from repro.parallel import sharding as shd
        # pin the EP layout: token shards on DP axes, experts on "model" —
        # building h from xt is then exactly one all-to-all.
        h = shd.constrain(h, ("batch", "model", None, None))

    g = jnp.einsum("gecd,edf->gecf", h, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", h, p["w_up"].astype(x.dtype))
    o = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u,
                   p["w_down"].astype(x.dtype))
    o = o.reshape(G, E * C, D)

    contrib = jnp.take_along_axis(o, dest[..., None], 1) \
        * (sw * keep)[..., None]
    y = jax.vmap(lambda acc, t, c: acc.at[t].add(c))(
        jnp.zeros((G, Tl, D), x.dtype), stok, contrib)

    y = y.reshape(T, D)
    if mc.n_shared:
        y = y + nn.mlp_apply(p["shared"], xt.reshape(T, D), "swiglu")
    return y.reshape(B, S, D), mc.aux_loss_coef * aux


def moe_apply_dense(p, cfg, x):
    """Reference dense-dispatch MoE (O(E) flops) for correctness tests."""
    mc = cfg.moe
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    w, idx, aux = router_topk(nn.linear(xt, p["router"]), mc.top_k)
    combine = jnp.zeros((B * S, mc.n_experts), x.dtype)
    combine = combine.at[jnp.arange(B * S)[:, None], idx].set(
        w.astype(x.dtype))
    g = jnp.einsum("td,edf->tef", xt, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("td,edf->tef", xt, p["w_up"].astype(x.dtype))
    o = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u,
                   p["w_down"].astype(x.dtype))
    y = jnp.einsum("ted,te->td", o, combine)
    if mc.n_shared:
        y = y + nn.mlp_apply(p["shared"], xt, "swiglu")
    return y.reshape(B, S, D), mc.aux_loss_coef * aux

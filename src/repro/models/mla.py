"""Multi-head latent attention (DeepSeek-V2 / MiniCPM3).

Q path: optional low-rank (q_lora) projection; per-head dims split into a
non-positional part (qk_nope) and a RoPE part (qk_rope).
KV path: a shared low-rank latent c_kv (kv_lora) is up-projected to K_nope
and V; a single shared RoPE key k_rope comes straight from x.

The decode cache stores only (c_kv, k_rope) — the paper's compressed cache —
and up-projects per step. (The weight-absorbed decode variant is a perf
iteration, see EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import modules as nn


class MLACache(NamedTuple):
    c_kv: jax.Array      # [B, S_max, R]   compressed latent
    k_rope: jax.Array    # [B, S_max, Dr]  shared rope key

    @staticmethod
    def init(batch, max_len, kv_lora, d_rope, dtype=jnp.bfloat16):
        return MLACache(
            jnp.zeros((batch, max_len, kv_lora), dtype),
            jnp.zeros((batch, max_len, d_rope), dtype),
        )


def mla_init(key, cfg):
    m = cfg.mla
    d = cfg.d_model
    h = cfg.n_heads
    dq = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if m.q_lora_rank:
        p["w_dq"] = nn.dense_init(ks[0], d, m.q_lora_rank)
        p["q_norm"] = jnp.ones((m.q_lora_rank,))
        p["w_uq"] = nn.dense_init(ks[1], m.q_lora_rank, (h, dq))
    else:
        p["w_q"] = nn.dense_init(ks[1], d, (h, dq))
    p["w_dkv"] = nn.dense_init(ks[2], d, m.kv_lora_rank)
    p["kv_norm"] = jnp.ones((m.kv_lora_rank,))
    p["w_uk"] = nn.dense_init(ks[3], m.kv_lora_rank, (h, m.qk_nope_head_dim))
    p["w_uv"] = nn.dense_init(ks[4], m.kv_lora_rank, (h, m.v_head_dim))
    p["w_kr"] = nn.dense_init(ks[5], d, m.qk_rope_head_dim)
    p["wo"] = nn.dense_init(ks[6], h * m.v_head_dim, d)
    return p


def _mla_q(p, cfg, x, positions):
    m = cfg.mla
    if m.q_lora_rank:
        cq = nn.rms_norm(nn.linear(x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
        q = nn.linear(cq, p["w_uq"])
    else:
        q = nn.linear(x, p["w_q"])                          # [B,S,H,dq]
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = nn.apply_rope(q[..., m.qk_nope_head_dim:], positions,
                           cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(p, cfg, x, positions):
    c_kv = nn.linear(x, p["w_dkv"])                         # [B,S,R]
    k_rope = nn.apply_rope(
        nn.linear(x, p["w_kr"])[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]                                           # [B,S,Dr]
    return c_kv, k_rope


def _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, q_pos, kv_pos,
                softcap: float = 0.0):
    """Attention over (possibly cached) latents."""
    m = cfg.mla
    ckn = nn.rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_nope = nn.linear(ckn, p["w_uk"])                      # [B,Skv,H,dn]
    v = nn.linear(ckn, p["w_uv"])                           # [B,Skv,H,dv]
    # NOTE §Perf iteration 6a: forcing these head-sharded ("model") was
    # REFUTED — the latents are seq-sharded, so the constraint added a
    # resharding step (collective 1.58s -> 2.23s). Left unconstrained.
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s_nope = jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) * scale
    ok = (kv_pos[:, None, :] <= q_pos[:, :, None]) & (kv_pos[:, None, :] >= 0)
    scores = scores + jnp.where(ok, 0.0, -jnp.inf)[:, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    B, S, H, Dv = out.shape
    return nn.linear(out.reshape(B, S, H * Dv), p["wo"])


def _mla_attend_absorbed(p, cfg, q_nope, q_rope, c_kv, k_rope, q_pos,
                         kv_pos):
    """Weight-absorbed attention in the compressed latent space (the
    DeepSeek-V2 deployment trick, §Perf): instead of up-projecting the
    whole cache to K/V per step, fold W_uk into the query and W_uv into
    the output:
        score = (W_uk^T q_nope)^T c_kv + q_rope^T k_rope
        out   = W_uv^T (softmax(score) c_kv)
    Per-step FLOPs drop from O(S*R*H*(dn+dv)) to O(H*R*(dn+dv) + S*H*R),
    and cache traffic is one read of (c_kv, k_rope)."""
    m = cfg.mla
    ckn = nn.rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)      # [B,Skv,R]
    # q~ [B,Sq,H,R]: absorb W_uk [R,H,dn] into the query
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope,
                       p["w_uk"].astype(q_nope.dtype))
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s_nope = jnp.einsum("bqhr,bkr->bhqk", q_lat, ckn,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) * scale
    ok = (kv_pos[:, None, :] <= q_pos[:, :, None]) & (kv_pos[:, None, :] >= 0)
    scores = scores + jnp.where(ok, 0.0, -jnp.inf)[:, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", w, ckn)             # [B,Sq,H,R]
    out = jnp.einsum("bqhr,rhd->bqhd", o_lat,
                     p["w_uv"].astype(o_lat.dtype))
    B, S, H, Dv = out.shape
    return nn.linear(out.reshape(B, S, H * Dv), p["wo"])


def mla_apply(p, cfg, x, positions, cache: Optional[MLACache] = None,
              cache_pos=None, kv_valid=None):
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_latents(p, cfg, x, positions)
    if cache is None:
        return _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope,
                           positions, positions), None
    S = x.shape[1]
    S_max = cache.c_kv.shape[1]
    newc = jax.lax.dynamic_update_slice_in_dim(
        cache.c_kv, c_kv.astype(cache.c_kv.dtype), cache_pos, axis=1)
    newr = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, k_rope.astype(cache.k_rope.dtype), cache_pos, axis=1)
    cache = MLACache(newc, newr)
    if kv_valid is None:
        kv_valid = jnp.full((x.shape[0],), 0, jnp.int32) + cache_pos + S
    kv_pos = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32)[None],
                              (x.shape[0], S_max))
    kv_pos = jnp.where(kv_pos < kv_valid[:, None], kv_pos, -1)
    attend = _mla_attend_absorbed if cfg.mla_absorb else _mla_attend
    y = attend(p, cfg, q_nope, q_rope, newc, newr, positions, kv_pos)
    return y, cache

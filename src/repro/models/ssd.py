"""Mamba-2 SSD (state-space duality) block.

Block: in_proj -> (z, x, B, C, dt); causal conv1d on (x, B, C); SSD scan
with scalar-per-head decay A; gated RMSNorm on z; out_proj.

SSD chunked algorithm (Dao & Gu 2024, sec. 6): split the sequence into
chunks of length Q. Within a chunk the output is a masked (C B^T) attention
("duality"); across chunks a small [H, N, P] state is carried by a scan.

Decode carries (conv windows, ssd state) in `SSDState`.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import modules as nn


class SSDState(NamedTuple):
    h: jax.Array          # [B, H, N, P] ssd state
    conv_x: jax.Array     # [B, W-1, H*P]
    conv_B: jax.Array     # [B, W-1, G*N]
    conv_C: jax.Array     # [B, W-1, G*N]

    @staticmethod
    def init(batch, n_heads, d_state, head_dim, conv_width, n_groups,
             dtype=jnp.float32):
        w = conv_width - 1
        return SSDState(
            jnp.zeros((batch, n_heads, d_state, head_dim), dtype),
            jnp.zeros((batch, w, n_heads * head_dim), dtype),
            jnp.zeros((batch, w, n_groups * d_state), dtype),
            jnp.zeros((batch, w, n_groups * d_state), dtype),
        )


def ssd_dims(cfg):
    sc = cfg.ssd
    d_inner = sc.expand * cfg.d_model
    n_heads = d_inner // sc.head_dim
    return d_inner, n_heads


def ssd_init(key, cfg):
    sc = cfg.ssd
    d = cfg.d_model
    d_inner, n_heads = ssd_dims(cfg)
    gn = sc.n_groups * sc.d_state
    ks = jax.random.split(key, 6)
    return {
        # fused input projection -> [z, x, B, C, dt]
        "w_in": nn.dense_init(ks[0], d, 2 * d_inner + 2 * gn + n_heads),
        "conv_x": nn.conv1d_init(ks[1], sc.conv_width, d_inner),
        "conv_B": nn.conv1d_init(ks[2], sc.conv_width, gn),
        "conv_C": nn.conv1d_init(ks[3], sc.conv_width, gn),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)),
        "D": jnp.ones((n_heads,)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (n_heads,),
                                       minval=np.log(1e-3),
                                       maxval=np.log(1e-1))))),
        "norm": jnp.ones((d_inner,)),
        "w_out": nn.dense_init(ks[5], d_inner, d),
    }


def _split_in(cfg, proj):
    sc = cfg.ssd
    d_inner, n_heads = ssd_dims(cfg)
    gn = sc.n_groups * sc.d_state
    z, xs, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn],
        axis=-1)
    return z, xs, Bm, Cm, dt


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD scan.

    x  [B, S, H, P]   inputs (head_dim P)
    dt [B, S, H]      positive step sizes
    A  [H]            negative decay rates (A < 0)
    Bm [B, S, G, N], Cm [B, S, G, N] with H % G == 0
    h0 [B, H, N, P]   optional initial state
    Returns (y [B, S, H, P], h_last [B, H, N, P]).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    xb = x.reshape(Bsz, nc, chunk, H, P)
    dtb = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bb = Bm.reshape(Bsz, nc, chunk, G, N)
    Cb = Cm.reshape(Bsz, nc, chunk, G, N)
    # expand groups to heads
    Bb = jnp.repeat(Bb, rep, axis=3)                    # [B,nc,Q,H,N]
    Cb = jnp.repeat(Cb, rep, axis=3)

    dA = dtb * A.astype(jnp.float32)                    # [B,nc,Q,H] (<0)
    cum = jnp.cumsum(dA, axis=2)                        # within-chunk cumsum
    # seg[b,c,i,j,h] = sum_{t=j+1..i} dA = cum_i - cum_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,Q(i),Q(j),H]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool), 0)[None, None, :, :,
                                                         None]
    L = jnp.where(causal, jnp.exp(seg), 0.0)

    xdt = xb * dtb[..., None]                           # weight inputs by dt
    # intra-chunk (dual / attention-like) term
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cb, Bb).astype(jnp.float32)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores * L,
                         xdt.astype(jnp.float32))

    # chunk-final states: sum_j exp(cum_Q - cum_j) B_j x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)     # [B,nc,Q,H]
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", Bb,
                        decay_to_end, xdt.astype(jnp.float32))

    # scan chunk states: h_c = exp(sum dA_c) h_{c-1} + states_c
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # [B,nc,H]

    def comb(c1, c2):
        a1, s1 = c1
        a2, s2 = c2
        return a1 * a2, a2[..., None, None] * s1 + s2

    a_all, h_all = jax.lax.associative_scan(
        comb, (chunk_decay, states), axis=1)            # h after each chunk
    if h0 is not None:
        h0f = h0.astype(jnp.float32)
        h_all = h_all + a_all[..., None, None] * h0f[:, None]
    # state entering chunk c
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_all[:, :1]) if h0 is None
         else h0.astype(jnp.float32)[:, None],
         h_all[:, :-1]], axis=1)                        # [B,nc,H,N,P]

    # inter-chunk contribution: C_i exp(cum_i) h_prev
    in_decay = jnp.exp(cum)                             # [B,nc,Q,H]
    y_inter = jnp.einsum("bcihn,bcih,bchnp->bcihp", Cb, in_decay, h_prev)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), h_all[:, -1]


def ssd_step(x_t, dt_t, A, B_t, C_t, h):
    """Single decode step. x_t [B,H,P], dt_t [B,H], B_t/C_t [B,G,N],
    h [B,H,N,P] -> (y [B,H,P], h')."""
    G = B_t.shape[1]
    H = x_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t, rep, axis=1)                   # [B,H,N]
    Ch = jnp.repeat(C_t, rep, axis=1)
    dtf = dt_t.astype(jnp.float32)
    a = jnp.exp(dtf * A.astype(jnp.float32))            # [B,H]
    upd = jnp.einsum("bhn,bhp->bhnp", Bh,
                     (x_t * dt_t[..., None]).astype(jnp.float32))
    h = a[..., None, None] * h.astype(jnp.float32) + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h)
    return y.astype(x_t.dtype), h


def ssd_apply(p, cfg, x, state: Optional[SSDState] = None):
    """x [B,S,D] -> (y [B,S,D], new_state)."""
    sc = cfg.ssd
    d_inner, n_heads = ssd_dims(cfg)
    proj = nn.linear(x, p["w_in"])
    z, xs, Bm, Cm, dt = _split_in(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    B_, S, _ = x.shape
    if state is None or S > 1:
        if state is None:
            xs_c = nn.conv1d_apply(p["conv_x"], xs)
            Bc = nn.conv1d_apply(p["conv_B"], Bm)
            Cc = nn.conv1d_apply(p["conv_C"], Cm)
            h0 = None
        else:  # chunked prefill continuation
            def warm(pc, seq, win):
                full = jnp.concatenate([win.astype(seq.dtype), seq], 1)
                return (nn.conv1d_apply(pc, full)[:, win.shape[1]:],
                        full[:, -(sc.conv_width - 1):, :])
            xs_c, wx = warm(p["conv_x"], xs, state.conv_x)
            Bc, wb = warm(p["conv_B"], Bm, state.conv_B)
            Cc, wc = warm(p["conv_C"], Cm, state.conv_C)
            h0 = state.h
        xs_c = jax.nn.silu(xs_c)
        Bc = jax.nn.silu(Bc)
        Cc = jax.nn.silu(Cc)
        xh = xs_c.reshape(B_, S, n_heads, sc.head_dim)
        Bh = Bc.reshape(B_, S, sc.n_groups, sc.d_state)
        Ch = Cc.reshape(B_, S, sc.n_groups, sc.d_state)
        dth = dt.reshape(B_, S, n_heads)
        qc = min(sc.chunk, S)
        while S % qc:                                   # static shapes
            qc //= 2
        if cfg.use_pallas and state is None:
            from repro.kernels.ssd_scan import ops as ssd_ops
            y, h_last = ssd_ops.ssd(xh, dth, A, Bh, Ch, chunk=qc)
        else:
            y, h_last = ssd_chunked(xh, dth, A, Bh, Ch, chunk=qc, h0=h0)
        y = y + xh * p["D"].astype(y.dtype)[None, None, :, None]
        y = y.reshape(B_, S, d_inner)
        new_state = None
        if state is not None:
            new_state = SSDState(h_last, wx.astype(state.conv_x.dtype),
                                 wb.astype(state.conv_B.dtype),
                                 wc.astype(state.conv_C.dtype))
    else:  # single-token decode
        xt, wx = nn.conv1d_step(p["conv_x"], xs[:, 0], state.conv_x)
        Bt, wb = nn.conv1d_step(p["conv_B"], Bm[:, 0], state.conv_B)
        Ct, wc = nn.conv1d_step(p["conv_C"], Cm[:, 0], state.conv_C)
        xt = jax.nn.silu(xt)
        Bt = jax.nn.silu(Bt)
        Ct = jax.nn.silu(Ct)
        xh = xt.reshape(B_, n_heads, sc.head_dim)
        y, h = ssd_step(
            xh, dt.reshape(B_, 1, n_heads)[:, 0], A,
            Bt.reshape(B_, sc.n_groups, sc.d_state),
            Ct.reshape(B_, sc.n_groups, sc.d_state), state.h)
        y = y + xh * p["D"].astype(y.dtype)[None, :, None]
        y = y.reshape(B_, 1, d_inner)
        new_state = SSDState(h, wx.astype(state.conv_x.dtype),
                             wb.astype(state.conv_B.dtype),
                             wc.astype(state.conv_C.dtype))

    y = nn.rms_norm(y * jax.nn.silu(z[:, :y.shape[1]]), p["norm"],
                    cfg.norm_eps)
    return nn.linear(y, p["w_out"]), new_state

"""Language-model wrapper: embeddings, transformer stack, heads, losses,
and the canonical train/prefill/decode entry points used by the launcher,
dry-run, benchmarks and serving engine.

Batch dict conventions
----------------------
training (`loss_fn` / `train step`):
    tokens  [B, S]  or [B, K, S] (multi-codebook, musicgen)
    labels  same shape, -100 = ignore
    prefix_embeds [B, P, D] optional (paligemma patch embeddings, stub
        frontend), prepended to the token embeddings; prefix positions are
        bidirectional when cfg.prefix_lm.
serving:
    prefill(params, tokens, caches, ...) -> (logits_last, caches)
    decode_step(params, token, pos, caches, ...) -> (logits, caches)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import modules as nn
from repro.models import transformer
from repro.parallel import sharding as shd

IGNORE = -100


def lm_init(key, cfg) -> Dict[str, Any]:
    cfg.validate()
    ks = jax.random.split(key, 4)
    K = cfg.n_codebooks
    V = cfg.vocab_padded
    p: Dict[str, Any] = {
        "embed": nn.truncated_normal(ks[0], (K, V, cfg.d_model), 0.02)
        if K > 1 else nn.truncated_normal(ks[0], (V, cfg.d_model), 0.02),
        "stack": transformer.stack_init(ks[1], cfg),
        "final_norm": jnp.ones((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        shape = (K, cfg.d_model, V) if K > 1 else (cfg.d_model, V)
        p["head"] = nn.truncated_normal(ks[2], shape, 0.02)
    return p


def _embed(p, cfg, tokens):
    dt = jnp.dtype(cfg.dtype)
    if cfg.n_codebooks > 1:           # tokens [B, K, S]
        embs = []
        for k in range(cfg.n_codebooks):
            embs.append(p["embed"][k].astype(dt)[tokens[:, k]])
        x = sum(embs)
    else:
        x = p["embed"].astype(dt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.embed_scale, dt)
    return x


def _head(p, cfg, x):
    if cfg.tie_embeddings:
        if cfg.n_codebooks > 1:
            w = p["embed"].astype(x.dtype)           # [K, V, D]
            logits = jnp.einsum("bsd,kvd->bksv", x, w)
        else:
            logits = nn.linear(x, p["embed"].astype(x.dtype).T)
    else:
        w = p["head"].astype(x.dtype)
        if cfg.n_codebooks > 1:
            logits = jnp.einsum("bsd,kdv->bksv", x, w)
        else:
            logits = nn.linear(x, w)
    if cfg.vocab_padded != cfg.vocab:   # mask padding rows
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = logits + jnp.where(pad_mask, -1e9, 0.0).astype(logits.dtype)
    return logits


def forward(p, cfg, tokens, prefix_embeds=None, positions=None,
            caches=None, cache_pos=None, kv_valid=None,
            head_mode: str = "all"):
    """Full forward. head_mode: "all" | "last" (only the final position's
    logits — prefill) | "none" (return final hidden states — chunked loss).
    Returns (logits_or_hidden, new_caches, aux_loss)."""
    x = _embed(p, cfg, tokens)
    B = x.shape[0]
    n_pre = 0
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(x.dtype)
        if cfg.embed_scale:
            pe = pe * jnp.asarray(cfg.embed_scale, x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
        n_pre = prefix_embeds.shape[1]
    S = x.shape[1]
    if positions is None:
        base = 0 if cache_pos is None else cache_pos
        positions = base + jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    prefix_len = None
    if cfg.prefix_lm and n_pre:
        prefix_len = jnp.full((B,), n_pre, jnp.int32)
    x = shd.constrain(x, ("batch", "seq", None))
    x, new_caches, aux = transformer.stack_apply(
        p["stack"], cfg, x, positions, prefix_len=prefix_len,
        caches=caches, cache_pos=cache_pos, kv_valid=kv_valid)
    x = nn.rms_norm(x, p["final_norm"], cfg.norm_eps)
    if n_pre:
        x = x[:, n_pre:]
    if head_mode == "none":
        return x, new_caches, aux
    if head_mode == "last":
        x = x[:, -1:]
    logits = _head(p, cfg, x)
    return logits, new_caches, aux


def _ce_from_logits(cfg, logits, labels):
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    mask = labels != IGNORE
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    return ((logz - gold) * mask).sum(), mask.sum()


def loss_fn(p, cfg, batch, loss_chunk: int = 1024
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean next-token cross entropy (+ MoE aux loss).

    The head + CE run in sequence chunks so the full [B, S, V] fp32 logits
    tensor is never materialized (vocab-sharded head stays sharded)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    hidden, _, aux = forward(p, cfg, tokens,
                             prefix_embeds=batch.get("prefix_embeds"),
                             head_mode="none")
    S = hidden.shape[1]
    if loss_chunk and S > loss_chunk and S % loss_chunk == 0:
        nc = S // loss_chunk
        # [B, S, D] -> [nc, B, c, D]; labels [..., S] -> [nc, ..., c]
        hs = jnp.moveaxis(
            hidden.reshape(hidden.shape[0], nc, loss_chunk, -1), 1, 0)
        lab = jnp.moveaxis(
            labels.reshape(*labels.shape[:-1], nc, loss_chunk), -2, 0)

        def chunk_ce(carry, xs):
            h, l = xs
            logits = _head(p, cfg, h)
            nll, n = _ce_from_logits(cfg, logits, l)
            return (carry[0] + nll, carry[1] + n), None

        (nll_sum, n_sum), _ = jax.lax.scan(
            chunk_ce, (jnp.float32(0.0), jnp.int32(0)), (hs, lab))
    else:
        logits = _head(p, cfg, hidden)
        nll_sum, n_sum = _ce_from_logits(cfg, logits, labels)
    denom = jnp.maximum(n_sum, 1)
    ce = nll_sum / denom
    total = ce + aux
    return total, {"loss": total, "ce": ce, "aux": aux,
                   "ntok": denom.astype(jnp.float32)}


# ---------------------------------------------------------------------------
# serving entry points
# ---------------------------------------------------------------------------
def init_caches(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return transformer.stack_cache_init(cfg, batch, max_len, dtype)


def prefill(p, cfg, tokens, caches, prefix_embeds=None, kv_valid=None):
    """Prefill from position 0. Returns (last_logits, caches)."""
    logits, caches, _ = forward(p, cfg, tokens, prefix_embeds=prefix_embeds,
                                caches=caches, cache_pos=0,
                                kv_valid=kv_valid, head_mode="last")
    return logits[:, 0] if cfg.n_codebooks == 1 else logits[:, :, 0], caches


def decode_step(p, cfg, token, pos: int | jax.Array, caches, kv_valid=None,
                positions=None):
    """One decode step. token [B] (or [B, K]); pos scalar cache offset."""
    if cfg.n_codebooks > 1:
        tok = token[:, :, None]              # [B, K, 1]
    else:
        tok = token[:, None]                 # [B, 1]
    logits, caches, _ = forward(p, cfg, tok, caches=caches, cache_pos=pos,
                                kv_valid=kv_valid, positions=positions)
    out = logits[:, 0] if cfg.n_codebooks == 1 else logits[:, :, 0]
    return out, caches


def param_count(p) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(p)))


def model_flops_per_token(cfg, n_params: Optional[int] = None,
                          params=None) -> float:
    """6*N per token for training (fwd+bwd); N = active params."""
    n = n_params if n_params is not None else active_param_count(cfg, params)
    return 6.0 * n


def active_param_count(cfg, params=None) -> int:
    """Active (per-token) parameter count: embeddings + non-expert weights +
    top_k/E of expert weights + shared experts."""
    if params is None:
        raise ValueError("need params")
    total = param_count(params)
    if cfg.mlp_type != "moe":
        return total
    # subtract inactive expert fraction
    def expert_size(tree):
        s = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            keys = [str(getattr(k, "key", getattr(k, "name", "")))
                    for k in path]
            is_expert = (
                any(k in ("w_gate", "w_up", "w_down") for k in keys)
                and "mlp" in keys and "shared" not in keys
                and leaf.ndim >= 3
                and cfg.moe.n_experts in leaf.shape[:-2]
            )
            if is_expert:
                s += int(np.prod(leaf.shape))
        return s
    e_total = expert_size(params)
    frac = cfg.moe.top_k / cfg.moe.n_experts
    return int(total - e_total * (1.0 - frac))

"""GQA/MQA/MHA attention with causal / sliding-window / prefix-LM masks,
a KV-cache decode path, and an optional Pallas flash kernel.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import modules as nn


class KVCache(NamedTuple):
    k: jax.Array       # [B, S_max, K, Dh]
    v: jax.Array       # [B, S_max, K, Dh]

    @staticmethod
    def init(batch, max_len, n_kv, d_head, dtype=jnp.bfloat16):
        shape = (batch, max_len, n_kv, d_head)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


class WindowKVCache(NamedTuple):
    """Ring buffer holding only the trailing `W` positions (local attn)."""
    k: jax.Array       # [B, W, K, Dh]
    v: jax.Array       # [B, W, K, Dh]
    pos: jax.Array     # [W] absolute positions (-1 = empty slot)

    @staticmethod
    def init(batch, window, n_kv, d_head, dtype=jnp.bfloat16):
        shape = (batch, window, n_kv, d_head)
        return WindowKVCache(jnp.zeros(shape, dtype),
                             jnp.zeros(shape, dtype),
                             jnp.full((window,), -1, jnp.int32))

    def update(self, k, v, cache_pos):
        """Write the last min(S, W) tokens of k/v (absolute start
        cache_pos) into the ring. Returns the new cache."""
        B, S = k.shape[0], k.shape[1]
        W = self.k.shape[1]
        T = min(S, W)
        src0 = S - T
        new_abs = cache_pos + src0 + jnp.arange(T, dtype=jnp.int32)
        slots = new_abs % W
        nk = self.k.at[:, slots].set(k[:, src0:].astype(self.k.dtype))
        nv = self.v.at[:, slots].set(v[:, src0:].astype(self.v.dtype))
        npos = self.pos.at[slots].set(new_abs)
        return WindowKVCache(nk, nv, npos)


def attn_init(key, cfg):
    ks = jax.random.split(key, 4)
    d, h, k_, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": nn.dense_init(ks[0], d, (h, dh)),
        "wk": nn.dense_init(ks[1], d, (k_, dh)),
        "wv": nn.dense_init(ks[2], d, (k_, dh)),
        "wo": nn.dense_init(ks[3], h * dh, d, std=1.0 / np.sqrt(h * dh)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh))
        p["bk"] = jnp.zeros((k_, dh))
        p["bv"] = jnp.zeros((k_, dh))
    return p


def _mask_bias(q_pos, kv_pos, window: int, prefix_len=None):
    """Additive mask bias [B, 1, Sq, Skv] (0 or -inf).

    q_pos/kv_pos: [B, Sq] / [B, Skv] absolute positions (-1 = invalid slot).
    window > 0 limits attention to the trailing `window` positions.
    prefix_len [B] (optional): bidirectional attention within the prefix.
    """
    q = q_pos[:, :, None]            # [B, Sq, 1]
    k = kv_pos[:, None, :]           # [B, 1, Skv]
    ok = (k <= q) & (k >= 0)
    if window:
        ok &= k > q - window
    if prefix_len is not None:
        pl = prefix_len[:, None, None]
        ok |= (k < pl) & (q < pl) & (k >= 0)
    return jnp.where(ok, 0.0, -jnp.inf)[:, None, :, :].astype(jnp.float32)


def sdpa(q, k, v, bias, softcap: float = 0.0):
    """q [B,Sq,H,Dh], k/v [B,Skv,K,Dh] with H = K*G. Returns [B,Sq,H,Dh].

    Scores accumulate in fp32 via preferred_element_type (NOT a post-cast:
    a cast after the dot makes XLA upcast the dot *operands* to f32, which
    doubles every collective the partitioner inserts around the einsum —
    measured 2x on the dry-run; see EXPERIMENTS.md §Perf)."""
    B, Sq, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    q = q.reshape(B, Sq, K, G, Dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(Dh)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = scores + bias[:, :, None, :, :]      # bias [B,1,Sq,Skv]
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H, Dh)


def attn_apply(p, cfg, x, positions, prefix_len=None, window: int = 0,
               cache: Optional[KVCache] = None, cache_pos=None,
               kv_valid=None):
    """Full attention forward.

    Training/prefill: cache=None, x [B, S, D].
    With cache: appends K/V at scalar offset `cache_pos` and attends over
    the cache; `kv_valid` [B] bounds each row's valid cache length
    (defaults to cache_pos + S).
    """
    q = nn.linear(x, p["wq"], p.get("bq"))        # [B,S,H,Dh]
    k = nn.linear(x, p["wk"], p.get("bk"))
    v = nn.linear(x, p["wv"], p.get("bv"))
    q = nn.apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
    k = nn.apply_rope(k, positions, cfg.rope_theta, cfg.rotary_pct)

    if cache is None:
        bias = _mask_bias(positions, positions, window, prefix_len)
        out = _sdpa_dispatch(cfg, q, k, v, bias, positions, window,
                             prefix_len)
    elif isinstance(cache, WindowKVCache):
        S = x.shape[1]
        cache = cache.update(k, v, cache_pos)
        if S > 1:
            # windowed prefill: attend within the fresh sequence only
            # (window <= S assumed; the ring now holds the trailing W)
            bias = _mask_bias(positions, positions, window, prefix_len)
            out = _sdpa_dispatch(cfg, q, k, v, bias, positions, window,
                                 prefix_len)
        else:
            if kv_valid is None:
                kv_valid = (jnp.zeros((x.shape[0],), jnp.int32)
                            + cache_pos + S)
            kv_pos = jnp.broadcast_to(cache.pos[None],
                                      (x.shape[0], cache.pos.shape[0]))
            kv_pos = jnp.where((kv_pos >= 0) & (kv_pos < kv_valid[:, None]),
                               kv_pos, -1)
            bias = _mask_bias(positions, kv_pos, window, prefix_len)
            out = sdpa(q, cache.k, cache.v, bias, cfg.logit_softcap)
    else:
        S = x.shape[1]
        S_max = cache.k.shape[1]
        newk = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), cache_pos, axis=1)
        newv = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), cache_pos, axis=1)
        cache = KVCache(newk, newv)
        if kv_valid is None:
            kv_valid = jnp.full((x.shape[0],), 0, jnp.int32) + cache_pos + S
        kv_pos = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32)[None],
                                  (x.shape[0], S_max))
        kv_pos = jnp.where(kv_pos < kv_valid[:, None], kv_pos, -1)
        bias = _mask_bias(positions, kv_pos, window, prefix_len)
        out = sdpa(q, newk, newv, bias, cfg.logit_softcap)
    B, S, H, Dh = out.shape
    y = nn.linear(out.reshape(B, S, H * Dh), p["wo"])
    return (y, cache) if cache is not None else (y, None)


def banded_sdpa(q, k, v, positions, window: int, softcap: float = 0.0):
    """Block-banded local attention: O(S*w) memory/compute instead of the
    naive O(S^2). Queries in blocks of `window` attend to their own block
    and the previous one. Requires S % window == 0."""
    B, S, H, Dh = q.shape
    K = k.shape[2]
    w = window
    nb = S // w
    qb = q.reshape(B, nb, w, H, Dh)
    kb = k.reshape(B, nb, w, K, Dh)
    vb = v.reshape(B, nb, w, K, Dh)
    zeros = jnp.zeros_like(kb[:, :1])
    k2 = jnp.concatenate([jnp.concatenate([zeros, kb[:, :-1]], 1), kb], 2)
    v2 = jnp.concatenate([jnp.concatenate([zeros, vb[:, :-1]], 1), vb], 2)
    posb = positions.reshape(B, nb, w)
    negs = jnp.full_like(posb[:, :1], -1)
    pos2 = jnp.concatenate(
        [jnp.concatenate([negs, posb[:, :-1]], 1), posb], 2)  # [B,nb,2w]
    G = H // K
    qb = qb.reshape(B, nb, w, K, G, Dh)
    scores = jnp.einsum("bnqkgd,bnskd->bnkgqs", qb, k2,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(Dh)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    ok = ((pos2[:, :, None, :] <= posb[:, :, :, None])
          & (pos2[:, :, None, :] > posb[:, :, :, None] - w)
          & (pos2[:, :, None, :] >= 0))              # [B,nb,w,2w]
    bias = jnp.where(ok, 0.0, -jnp.inf)[:, :, None, None, :, :]
    wgt = jax.nn.softmax(scores + bias, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnkgqs,bnskd->bnqkgd", wgt, v2)
    return out.reshape(B, S, H, Dh)


def _sdpa_dispatch(cfg, q, k, v, bias, positions, window, prefix_len):
    if cfg.use_pallas and prefix_len is None:
        from repro.kernels.flash_attention import ops as flash_ops
        return flash_ops.flash_attention(
            q, k, v, causal=True, window=window,
            softcap=cfg.logit_softcap)
    if (window and prefix_len is None and q.shape[1] == k.shape[1]
            and q.shape[1] % window == 0 and q.shape[1] >= 2 * window):
        return banded_sdpa(q, k, v, positions, window, cfg.logit_softcap)
    return sdpa(q, k, v, bias, cfg.logit_softcap)

"""Gradient compression utilities.

Two entry points:

* `fake_requantize(grads)` — per-tensor int8 symmetric quantize/dequantize of
  the gradient pytree. Under pjit the data-parallel all-reduce XLA emits will
  move int8-scaled values' *information content*; since GSPMD does not let us
  intercept its all-reduce directly, this models the accuracy effect while
  the explicit-collective path below models the bandwidth effect.

* `compressed_psum(x, axis)` — shard_map-compatible explicit int8
  compress -> psum -> dequantize, used by the shard_map DP trainer variant
  (`examples/train_tiny_lm.py --compress`) where we control the collective:
  bytes on the wire drop 4x (f32) / 2x (bf16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _q8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_tree(grads):
    return jax.tree.map(lambda g: _q8(g.astype(jnp.float32)), grads,
                        is_leaf=lambda x: hasattr(x, "dtype"))


def fake_requantize(grads):
    def f(g):
        q, s = _q8(g.astype(jnp.float32))
        return (q.astype(jnp.float32) * s).astype(g.dtype)
    return jax.tree.map(f, grads)


def compressed_psum(x, axis: str):
    """int8-compressed psum for use inside shard_map. Quantizes locally,
    sums int32 partial values (wire format int8 per shard), rescales by the
    max of per-shard scales."""
    q, s = _q8(x.astype(jnp.float32))
    s_max = jax.lax.pmax(s, axis)
    # renormalize local quanta to the common scale before summing
    q_common = jnp.round(q.astype(jnp.float32) * (s / s_max)).astype(
        jnp.int32)
    total = jax.lax.psum(q_common, axis)
    return total.astype(jnp.float32) * s_max

"""Sharding rules: parameters, optimizer state, batches and caches.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
  * batch (DP):       ("pod", "data")
  * FSDP (ZeRO-3):    parameters/optimizer state shard their d_model-ish dim
                      over "data"; XLA all-gathers per layer inside the scan.
  * TP (megatron):    heads / d_ff / vocab / experts shard over "model".
                      Non-divisible dims (e.g. 56 heads on 16) rely on
                      GSPMD's implicit padding; the waste shows up in the
                      roofline MODEL_FLOPS/HLO_FLOPS ratio.
  * EP:               MoE expert stacks shard experts over "model".
  * caches:           batch over DP axes; kv-heads over "model" when
                      divisible, else the sequence dim.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh, cfg=None) -> Tuple[str, ...]:
    if cfg is not None and getattr(cfg, "shard_strategy", "tp") == "ep_dp":
        return tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _path_keys(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "name", k))))
    return tuple(out)


# parameter rules keyed by leaf name -> spec WITHOUT the scan-stack axis.
# "F" marks the FSDP axis ("data"), "M" the tensor axis ("model").
_PARAM_RULES = {
    # attention
    "wq": ("F", "M", None), "wk": ("F", "M", None), "wv": ("F", "M", None),
    "bq": ("M", None), "bk": ("M", None), "bv": ("M", None),
    "wo": ("M", "F"),
    # MLA
    "w_q": ("F", "M", None),
    "w_dq": ("F", None), "w_uq": (None, "M", None),
    "w_dkv": ("F", None), "w_uk": (None, "M", None),
    "w_uv": (None, "M", None), "w_kr": ("F", None),
    "q_norm": (None,), "kv_norm": (None,),
    # dense MLP
    "w_gate": ("F", "M"), "w_up": ("F", "M"), "w_down": ("M", "F"),
    "b_up": ("M",), "b_down": (None,),
    # router
    "router": ("F", None),
    # rglru
    "w_x": ("F", "M"), "w_r": ("M", None), "w_i": ("M", None),
    "b_r": (None,), "b_i": (None,), "lam": ("M",), "w_out": ("M", "F"),
    # ssd
    "w_in": ("F", "M"), "A_log": ("M",), "D": ("M",), "dt_bias": ("M",),
    "norm": ("M",),
    # conv
    "w": (None, "M"), "b": ("M",),
    # norms / embeddings
    "ln1": (None,), "ln2": (None,), "final_norm": (None,),
    "embed": ("M", "F"), "head": ("F", "M"),
}

# expert-stacked leaves ([E, ...]) get "M" on the expert axis instead
_EXPERT_RULES = {
    "w_gate": ("M", "F", None), "w_up": ("M", "F", None),
    "w_down": ("M", None, "F"),
}


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return int(mesh.shape[ax])


def _fix_divisibility(spec, shape, mesh: Mesh):
    """jit argument shardings require exact divisibility. For every axis
    that does not divide its dim, move it to the largest *free* divisible
    dim (preferring trailing dims, e.g. heads -> head_dim), else drop it."""
    spec = list(spec)
    for i, ax in enumerate(spec):
        if ax is None:
            continue
        if shape[i] % _axis_size(mesh, ax) == 0:
            continue
        spec[i] = None
        for j in range(len(spec) - 1, -1, -1):
            if (spec[j] is None and j != i
                    and shape[j] % _axis_size(mesh, ax) == 0
                    and shape[j] >= _axis_size(mesh, ax)):
                spec[j] = ax
                break
    return tuple(spec)


def param_spec(path, leaf, cfg, mesh: Mesh) -> P:
    keys = _path_keys(path)
    name = keys[-1]
    scanned = "groups" in keys
    in_moe = ("mlp" in keys and "shared" not in keys
              and cfg.mlp_type == "moe")
    if name == "embed" and cfg.n_codebooks > 1:
        rule: Tuple = (None, "M", "F")
    elif name == "head" and cfg.n_codebooks > 1:
        rule = (None, "F", "M")
    elif in_moe and name in _EXPERT_RULES and leaf.ndim - int(scanned) == 3:
        rule = _EXPERT_RULES[name]
    elif name in _PARAM_RULES:
        rule = _PARAM_RULES[name]
    else:
        rule = (None,) * (leaf.ndim - int(scanned))
    if len(rule) != leaf.ndim - int(scanned):
        rule = (None,) * (leaf.ndim - int(scanned))
    ax = {"F": "data", "M": "model", None: None}
    if getattr(cfg, "shard_strategy", "tp") == "ep_dp":
        # only expert stacks use the model axis; everything else
        # replicates over it (pure-DP attention/MLP + EP)
        is_expert = in_moe and name in _EXPERT_RULES
        if not is_expert:
            ax = {"F": "data", "M": None, None: None}
    spec = tuple(ax[r] for r in rule)
    if scanned:
        spec = (None,) + spec
    spec = _fix_divisibility(spec, leaf.shape, mesh)
    return P(*spec)


def param_shardings(params, cfg, mesh: Mesh):
    """Pytree of NamedShardings matching `params` (works on abstract trees
    of ShapeDtypeStruct too)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [NamedSharding(mesh, param_spec(p, l, cfg, mesh))
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# batches & caches
# ---------------------------------------------------------------------------
def batch_spec(mesh: Mesh, ndim: int, shape=None, cfg=None) -> P:
    ax = batch_axes(mesh, cfg)
    if shape is not None and (len(shape) == 0
                              or shape[0] % _axis_size(mesh, ax) != 0):
        # retry without the model axis (ep_dp with a small batch)
        ax = batch_axes(mesh)
        if (len(shape) == 0 or shape[0] % _axis_size(mesh, ax) != 0):
            return P(*([None] * ndim))
    return P(ax, *([None] * (ndim - 1)))


def batch_shardings(batch, mesh: Mesh, cfg=None):
    return jax.tree.map(
        lambda x: NamedSharding(
            mesh, batch_spec(mesh, np.ndim(x), np.shape(x), cfg)), batch)


def cache_spec(path, leaf, cfg, mesh: Mesh) -> P:
    keys = _path_keys(path)
    name = keys[-1]
    scanned = "groups" in keys
    b = batch_axes(mesh)
    msz = model_axis_size(mesh)
    nd = leaf.ndim - int(scanned)
    if name in ("k", "v"):                      # [B, S, K, Dh]
        if cfg.n_kv_heads % msz == 0:
            rule: Tuple = (b, None, "model", None)
        else:
            rule = (b, "model", None, None)
    elif name == "c_kv" or name == "k_rope":    # [B, S, R/Dr]
        rule = (b, "model", None)
    elif name == "pos":                         # [W]
        rule = (None,)
    elif name == "h" and nd == 2:               # rglru state [B, R]
        rule = (b, "model")
    elif name == "h" and nd == 4:               # ssd state [B, H, N, P]
        rule = (b, "model", None, None)
    elif nd == 3:                               # conv windows [B, W-1, C]
        rule = (b, None, "model")
    else:
        rule = (b,) + (None,) * (nd - 1)
    if scanned:
        rule = (None,) + tuple(rule)
    rule = _fix_divisibility(tuple(rule), leaf.shape, mesh)
    return P(*rule)


def cache_shardings(caches, cfg, mesh: Mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    specs = [NamedSharding(mesh, cache_spec(p, l, cfg, mesh))
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# activation sharding policy (set around jit tracing; consulted by model code
# via `constrain`). Without explicit constraints GSPMD lets FSDP parameter
# shardings leak into the activations inside the layer scan (verified: full-
# batch activations with d_model sharded -> 170 GB/device temps on
# phi3/train_4k). The policy pins: batch -> DP axes, and optionally
# seq -> "model" (megatron sequence parallelism) on the residual stream.
# ---------------------------------------------------------------------------
_ACT_POLICY: dict = {}


class activation_policy:
    """Context manager: set the logical->mesh mapping for activations."""

    def __init__(self, mesh: Mesh, sequence_parallel: bool = False,
                 cfg=None):
        ep_dp = (cfg is not None
                 and getattr(cfg, "shard_strategy", "tp") == "ep_dp")
        self.new = {
            "mesh": mesh,
            "batch": batch_axes(mesh, cfg),
            "seq": "model" if (sequence_parallel and not ep_dp) else None,
        }

    def __enter__(self):
        global _ACT_POLICY
        self._old = dict(_ACT_POLICY)
        _ACT_POLICY.clear()
        _ACT_POLICY.update(self.new)
        return self

    def __exit__(self, *exc):
        global _ACT_POLICY
        _ACT_POLICY.clear()
        _ACT_POLICY.update(self._old)
        return False


def constrain(x, logical: Tuple[Any, ...]):
    """Apply with_sharding_constraint mapping logical axis names
    ("batch", "seq", None — or a literal mesh axis name like "model")
    through the active policy. No-op when no policy is set (single-device
    tests) or when a dim is not divisible by its mesh axis (e.g. decode's
    seq==1 under sequence parallelism)."""
    if not _ACT_POLICY:
        return x
    mesh = _ACT_POLICY["mesh"]

    def resolve(l):
        if isinstance(l, str):
            if l in _ACT_POLICY:
                return _ACT_POLICY.get(l)
            if l in mesh.axis_names:
                return l
            return None
        if isinstance(l, tuple):
            parts = []
            for e in l:
                r = resolve(e)
                if r is None:
                    continue
                parts.extend(r if isinstance(r, tuple) else (r,))
            return tuple(parts) or None
        return None

    spec = []
    for i, l in enumerate(logical):
        ax = resolve(l)
        if ax is not None:
            sizes = (np.prod([mesh.shape[a] for a in ax])
                     if isinstance(ax, tuple) else mesh.shape[ax])
            if x.shape[i] % int(sizes) != 0:
                ax = None
        spec.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))

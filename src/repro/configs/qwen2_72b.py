"""Qwen2-72B: GQA with QKV bias. [arXiv:2407.10671]
80L, d_model=8192, 64 heads / 8 KV, d_ff=29568, vocab=152064."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29568,
    vocab=152064,
    pattern=("attn",),
    mlp_type="swiglu",
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
)

"""DeepSeek-V2-Lite (16B total / 2.4B active): MLA + fine-grained MoE.
[arXiv:2405.04434] 27L, d_model=2048, 16 heads, kv_lora=512, qk_nope=128,
qk_rope=64, v_head=128 (no q_lora); MoE: 64 routed experts top-6 +
2 shared, expert d_ff=1408, first layer dense (d_ff=10944); vocab=102400."""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=10944,                  # dense (first-layer) MLP width
    vocab=102400,
    pattern=("attn",),
    mlp_type="moe",
    attn_impl="mla",
    mla=MLAConfig(q_lora_rank=0, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                  first_k_dense=1, capacity_factor=1.25),
    rope_theta=10000.0,
    tie_embeddings=False,
)

"""Phi-3-mini-3.8B: RoPE + SwiGLU decoder (kv=32 -> MHA). [arXiv:2404.14219]
32L, d_model=3072, 32 heads / 32 KV, d_ff=8192, vocab=32064."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_head=96,
    d_ff=8192,
    vocab=32064,
    pattern=("attn",),
    mlp_type="swiglu",
    rope_theta=10000.0,
    tie_embeddings=False,
)

"""DBRX-132B: fine-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base]
40L, d_model=6144, 48 heads / 8 KV, expert d_ff=10752, vocab=100352."""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=10752,
    vocab=100352,
    pattern=("attn",),
    mlp_type="moe",
    moe=MoEConfig(n_experts=16, top_k=4, n_shared=0, d_expert=10752,
                  first_k_dense=0, capacity_factor=1.25),
    rope_theta=500000.0,
    tie_embeddings=False,
)

"""Mamba2-780M: attention-free SSD (state-space duality). [arXiv:2405.21060]
48L, d_model=1536, expand=2 (d_inner=3072), ssm_state=128, head_dim=64,
vocab=50280. Sub-quadratic: runs the long_500k shape."""
from repro.configs.base import ModelConfig, SSDConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    n_layers=48,
    d_model=1536,
    n_heads=48,                  # d_inner / head_dim (bookkeeping only)
    n_kv_heads=48,
    d_head=64,
    d_ff=0,
    vocab=50280,
    pattern=("ssd",),
    mlp_type="none",
    ssd=SSDConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk=128, n_groups=1),
    tie_embeddings=True,
)

"""PaliGemma-3B: SigLIP vision frontend (STUB: `input_specs` supplies 256
precomputed patch embeddings) + gemma-2B decoder, prefix-LM attention.
[arXiv:2407.07726] 18L, d_model=2048, 8 heads / 1 KV (MQA), d_ff=16384
(GeGLU), vocab=257216."""
from repro.configs.base import ModelConfig

N_PATCHES = 256  # 224x224 / 14x14 SigLIP patches

CONFIG = ModelConfig(
    name="paligemma-3b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab=257216,
    pattern=("attn",),
    mlp_type="geglu",
    rope_theta=10000.0,
    prefix_lm=True,
    embed_scale=2048 ** 0.5,   # gemma embedding scale
    tie_embeddings=True,
    n_prefix_embeds=N_PATCHES,
)

"""Unified model configuration for all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3)."""
    q_lora_rank: int = 0          # 0 = direct q projection
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0             # always-on shared experts
    d_expert: int = 0             # expert FFN hidden size
    first_k_dense: int = 0        # leading layers use a dense MLP
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    aux_loss_coef: float = 0.01
    # >0: dispatch sort/pack runs independently within this many token
    # shards (aligned with the DP sharding) so no global sort collectives
    # are emitted — §Perf iteration for the MoE cells.
    n_dispatch_shards: int = 0


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """Griffin / RecurrentGemma recurrent block."""
    d_rnn: int = 0                # recurrent width (0 -> d_model)
    conv_width: int = 4
    c: float = 8.0                # RG-LRU gate sharpness


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    """Mamba-2 state-space duality block."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    n_groups: int = 1             # B/C groups (GVA-style)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # block pattern, repeated to cover n_layers. entries:
    #   "attn"   full (GQA/MLA) attention + MLP
    #   "local"  sliding-window attention + MLP
    #   "rglru"  RG-LRU recurrent block + MLP
    #   "ssd"    mamba-2 SSD block (no separate MLP)
    pattern: Tuple[str, ...] = ("attn",)
    mlp_type: str = "swiglu"      # swiglu | geglu | gelu | moe | none
    attn_impl: str = "gqa"        # gqa | mla
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    rglru: Optional[RGLRUConfig] = None
    ssd: Optional[SSDConfig] = None
    # attention details
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    qkv_bias: bool = False
    window: int = 0               # sliding window size for "local" blocks
    prefix_lm: bool = False       # bidirectional attention over the prefix
    logit_softcap: float = 0.0
    # embedding / head
    n_codebooks: int = 1          # musicgen: parallel codebook streams
    tie_embeddings: bool = True
    embed_scale: float = 0.0      # 0 -> 1.0; gemma uses sqrt(d_model)
    # norms / dtypes
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"       # compute dtype
    param_dtype: str = "float32"
    # training
    remat: str = "full"           # none | full | dots
    scan_layers: bool = True
    # sharding strategy: "tp" (FSDP x tensor-parallel, default) or
    # "ep_dp" (batch shards over ALL mesh axes incl. "model"; non-expert
    # params replicate over "model"; experts shard over "model" = pure
    # data-parallel attention + expert parallelism — the right mapping for
    # small-active-param MoE, §Perf iteration 7)
    shard_strategy: str = "tp"
    # kernels
    use_pallas: bool = False      # TPU-only fused kernels (tests use interpret)
    # decode-path optimization: MLA weight absorption (attention runs in the
    # compressed latent space; no per-step K/V expansion) — §Perf iteration.
    mla_absorb: bool = False
    # modality frontend stub: number of precomputed prefix embeddings
    n_prefix_embeds: int = 0      # e.g. paligemma image patches

    @property
    def pattern_full(self) -> Tuple[str, ...]:
        reps = -(-self.n_layers // len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]

    @property
    def vocab_padded(self) -> int:
        """Embedding/head rows padded to a multiple of 16 so the vocab dim
        shards across the model axis (Megatron-style padding; padded logits
        are masked to -inf in the head)."""
        return -(-self.vocab // 16) * 16

    @property
    def is_ssm_only(self) -> bool:
        return all(p == "ssd" for p in self.pattern)

    @property
    def supports_long_context(self) -> bool:
        """sub-quadratic archs: no full-attention block in the pattern."""
        return all(p in ("ssd", "rglru", "local") for p in self.pattern)

    def validate(self) -> None:
        assert self.n_layers > 0 and self.d_model > 0
        for p in self.pattern:
            assert p in ("attn", "local", "rglru", "ssd"), p
        if "local" in self.pattern:
            assert self.window > 0, "local blocks need a window"
        if self.mlp_type == "moe":
            assert self.moe is not None
        if self.attn_impl == "mla":
            assert self.mla is not None
        if "ssd" in self.pattern:
            assert self.ssd is not None
        if "rglru" in self.pattern:
            assert self.rglru is not None


def scaled_down(cfg: ModelConfig, n_layers: int = 2, d_model: int = 64,
                n_heads: int = 4, vocab: int = 512, **kw) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    n_kv = max(1, min(cfg.n_kv_heads * n_heads // max(cfg.n_heads, 1), n_heads))
    if cfg.n_kv_heads == cfg.n_heads:
        n_kv = n_heads
    if cfg.n_kv_heads == 1:
        n_kv = 1
    upd = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=n_kv, d_head=d_model // n_heads, d_ff=d_model * 3,
        vocab=vocab, window=min(cfg.window, 32) if cfg.window else 0,
        n_prefix_embeds=min(cfg.n_prefix_embeds, 4),
    )
    if cfg.mla is not None:
        upd["mla"] = MLAConfig(
            q_lora_rank=d_model // 2 if cfg.mla.q_lora_rank else 0,
            kv_lora_rank=d_model // 2, qk_nope_head_dim=d_model // n_heads,
            qk_rope_head_dim=max(4, d_model // n_heads // 2),
            v_head_dim=d_model // n_heads,
        )
    if cfg.moe is not None:
        upd["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, n_shared=min(cfg.moe.n_shared, 1),
            d_expert=d_model * 2,
            first_k_dense=min(cfg.moe.first_k_dense, 1),
        )
    if cfg.ssd is not None:
        upd["ssd"] = dataclasses.replace(
            cfg.ssd, d_state=16, head_dim=16, chunk=16)
    if cfg.rglru is not None:
        upd["rglru"] = dataclasses.replace(cfg.rglru, d_rnn=d_model)
    upd.update(kw)
    out = dataclasses.replace(cfg, **upd)
    out.validate()
    return out

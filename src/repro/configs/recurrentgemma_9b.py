"""RecurrentGemma-9B (Griffin): RG-LRU recurrent blocks + local attention in
a 2:1 pattern (recurrent, recurrent, local). [arXiv:2402.19427]
38L = 12 x (rglru, rglru, local) + 2 trailing rglru, d_model=4096,
16 heads / 1 KV (MQA) local attention with window 2048, d_ff=12288 (GeGLU),
vocab=256000. Sub-quadratic: runs the long_500k shape."""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    n_layers=38,                 # 12 x (rglru, rglru, local) + 2 rglru
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_head=256,
    d_ff=12288,
    vocab=256000,
    pattern=("rglru", "rglru", "local"),
    mlp_type="geglu",
    rglru=RGLRUConfig(d_rnn=4096, conv_width=4, c=8.0),
    window=2048,
    rope_theta=10000.0,
    embed_scale=4096 ** 0.5,
    tie_embeddings=True,
)

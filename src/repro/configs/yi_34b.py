"""Yi-34B: llama-architecture GQA decoder. [arXiv:2403.04652]
60L, d_model=7168, 56 heads / 8 KV, d_ff=20480, vocab=64000."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    vocab=64000,
    pattern=("attn",),
    mlp_type="swiglu",
    rope_theta=5000000.0,
    tie_embeddings=False,
)

"""Architecture config registry: `get_config(arch)` / `get_smoke_config`."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, scaled_down  # noqa: F401

ARCH_IDS: List[str] = [
    "minicpm3-4b",
    "yi-34b",
    "phi3-mini-3.8b",
    "qwen2-72b",
    "paligemma-3b",
    "musicgen-medium",
    "recurrentgemma-9b",
    "deepseek-v2-lite-16b",
    "dbrx-132b",
    "mamba2-780m",
]

_MODULES: Dict[str, str] = {
    "minicpm3-4b": "minicpm3_4b",
    "yi-34b": "yi_34b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "qwen2-72b": "qwen2_72b",
    "paligemma-3b": "paligemma_3b",
    "musicgen-medium": "musicgen_medium",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "dbrx-132b": "dbrx_132b",
    "mamba2-780m": "mamba2_780m",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    cfg: ModelConfig = mod.CONFIG
    cfg.validate()
    return cfg


def get_smoke_config(arch: str, **kw) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return scaled_down(get_config(arch), **kw)

"""MiniCPM3-4B: 62L dense decoder with MLA attention.
[hf:openbmb/MiniCPM3-4B] d_model=2560, 40 heads, d_ff=6400, vocab=73448,
q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64."""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_head=64,
    d_ff=6400,
    vocab=73448,
    pattern=("attn",),
    mlp_type="swiglu",
    attn_impl="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
    rope_theta=10000.0,
    embed_scale=12.0,          # mup-style scale_emb
    tie_embeddings=True,
)

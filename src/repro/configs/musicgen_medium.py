"""MusicGen-medium: decoder-only transformer over EnCodec tokens (STUB
frontend: `input_specs` supplies 4 parallel codebook token streams in the
delay pattern; the EnCodec encoder/decoder itself is out of scope).
[arXiv:2306.05284] 48L, d_model=1536, 24 heads (MHA), d_ff=6144, vocab=2048
per codebook, 4 codebooks with summed embeddings and parallel heads."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_head=64,
    d_ff=6144,
    vocab=2048,
    pattern=("attn",),
    mlp_type="gelu",
    rope_theta=10000.0,
    n_codebooks=4,
    tie_embeddings=False,
)

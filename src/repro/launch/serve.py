"""Serving launcher: continuous-batching engine + DAS dispatch.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-34b \
        --dispatcher das --rate 50 --requests 500
"""
from __future__ import annotations

import argparse

from repro import configs
from repro.serve import costmodel as cm
from repro.serve import dispatch as dsp
from repro.serve import engine as eng


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-34b", choices=configs.ARCH_IDS)
    ap.add_argument("--dispatcher", default="das",
                    choices=["lut", "etf", "das", "threshold"])
    ap.add_argument("--rate", type=float, default=50.0)
    ap.add_argument("--requests", type=int, default=300)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--chips-per-replica", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = eng.EngineConfig(n_replicas=args.replicas)
    spec = cm.ReplicaSpec("v5e", n_chips=args.chips_per_replica)
    mc = cm.ModelCost.from_config(configs.get_config(args.arch))

    if args.dispatcher == "lut":
        d = dsp.LUTDispatcher(args.replicas)
    elif args.dispatcher == "etf":
        d = dsp.ETFDispatcher()
    elif args.dispatcher == "threshold":
        d = dsp.ThresholdDispatcher(50.0, args.replicas)
    else:
        scen = [(r, 150, s) for r in (2, 10, 40, 120, 300) for s in (0, 1)]
        d = dsp.train_das_dispatcher(scen, cfg, spec, mc)
        print(f"trained DAS dispatcher: acc={d.train_accuracy:.3f} "
              f"slow-label-frac={d.label_slow_frac:.3f}")

    reqs = eng.poisson_requests(args.rate, args.requests, args.seed)
    res = eng.run_engine(reqs, d, cfg, spec, mc)
    print(f"arch={args.arch} dispatcher={args.dispatcher} rate={args.rate}")
    print(f"  mean latency {res.mean_latency_s*1e3:.1f} ms | p99 "
          f"{res.p99_latency_s*1e3:.1f} ms | ttft {res.mean_ttft_s*1e3:.1f}"
          f" ms | {res.throughput_rps:.1f} req/s")
    print(f"  energy {res.energy_j/1e3:.2f} kJ | EDP {res.edp:.0f} | "
          f"fast/slow dispatches {res.dispatch_fast}/{res.dispatch_slow}")
    return res


if __name__ == "__main__":
    main()

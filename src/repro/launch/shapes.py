"""The assigned input-shape suite and per-(arch x shape) applicability."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}
SHAPE_NAMES: Tuple[str, ...] = tuple(SHAPES)


def applicable(cfg, shape: str) -> Optional[str]:
    """None if the cell runs; else a skip reason (recorded in DESIGN.md)."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return ("full-attention decode at 524k context is quadratic-in-"
                "aggregate and exceeds HBM; run only for SSM/hybrid archs")
    return None


def cells(arch_cfgs) -> List[Tuple[str, str]]:
    out = []
    for arch, cfg in arch_cfgs.items():
        for s in SHAPE_NAMES:
            if applicable(cfg, s) is None:
                out.append((arch, s))
    return out

"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
        --smoke --steps 100 --ckpt-dir /tmp/run1 [--resume]

--smoke uses the reduced same-family config (CPU-runnable); the full config
is intended for real TPU meshes (and is exercised via the dry-run here).
Fault-tolerance flags: --inject-failure-at N simulates a node failure,
--microbatch M enables gradient accumulation, --compress int8 enables
gradient compression.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch import mesh as meshlib
from repro.train import optimizer as optim
from repro.train import trainer as tr


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--compress", default=None, choices=[None, "int8"])
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = meshlib.make_local_mesh(args.data_parallel, args.model_parallel)
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"mesh={dict(mesh.shape)} devices={len(jax.devices())}")

    data = Prefetcher(SyntheticLM(
        vocab=cfg.vocab, batch=args.batch, seq_len=args.seq,
        n_codebooks=cfg.n_codebooks))
    tcfg = tr.TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, microbatch=args.microbatch,
        grad_compression=args.compress)
    ocfg = optim.AdamWConfig(lr_peak=args.lr, warmup_steps=args.steps // 10,
                             total_steps=args.steps)
    t = tr.Trainer(tcfg, cfg, ocfg, mesh, data)
    if args.inject_failure_at is not None:
        t.inject_failure_at = args.inject_failure_at
    out = t.fit(resume=args.resume)
    print(f"done at step {out['step']}; restarts={out['restarts']} "
          f"stragglers={out['straggler_events']} "
          f"final loss={out['metrics'][-1]['loss']:.4f}")
    data.close()
    return out


if __name__ == "__main__":
    main()

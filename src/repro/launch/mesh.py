"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
CHIP_HBM_BYTES = 16e9           # v5e HBM capacity

"""Roofline analysis over the dry-run artifacts.

Per (arch x shape x mesh) cell, three per-chip time bounds (TPU v5e):

  compute_s    = dot_flops_per_dev / PEAK_FLOPS_BF16
  memory_s     = dot_bytes_per_dev / HBM_BW
  collective_s = collective_bytes_per_dev / ICI_BW

dot_flops / dot_bytes are trip-count-weighted matmul FLOPs / operand+output
bytes parsed from the partitioned HLO (launch.hlo_analysis) — XLA's own
cost_analysis counts scan bodies once and is unusable here (verified).
dot_bytes is an HBM-traffic model that assumes perfect fusion of
elementwise chains into the matmuls; collective bytes are per-chip output
shapes of all all-gather/all-reduce/reduce-scatter/all-to-all/
collective-permute ops, trip-weighted.

The dominant term is the bottleneck; `useful_ratio` =
MODEL_FLOPS / (dot_flops * n_devices) exposes remat/padding/attention
overhead versus the 6*N*D (or 2*N*D) ideal.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.launch import mesh as meshlib


def roofline_terms(cell: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if cell.get("status") != "ok" or "dot_flops_per_dev" not in cell:
        return None
    n_dev = cell["n_devices"]
    compute_s = cell["dot_flops_per_dev"] / meshlib.PEAK_FLOPS_BF16
    memory_s = cell["dot_bytes_per_dev"] / meshlib.HBM_BW
    # TPU-native byte accounting when available (the CPU backend's float
    # normalization stores bf16 as f32, doubling observed collectives)
    coll_bytes = sum(cell.get("collective_bytes_tpu",
                              cell["collective_bytes"]).values())
    collective_s = coll_bytes / meshlib.ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    model_flops_per_dev = cell["model_flops_global"] / n_dev
    useful_ratio = (model_flops_per_dev / cell["dot_flops_per_dev"]
                    if cell["dot_flops_per_dev"] else 0.0)
    # fraction of peak the chip would sustain if the dominant bound holds
    mfu_bound = model_flops_per_dev / meshlib.PEAK_FLOPS_BF16 / step_s \
        if step_s else 0.0
    return {
        **terms,
        "dominant": dominant,
        "step_time_bound_s": step_s,
        "useful_ratio": useful_ratio,
        "roofline_fraction": mfu_bound,
        "coll_bytes_per_dev": coll_bytes,
    }


def build_table(results: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    rows = []
    for cell in results:
        if cell.get("status") == "skipped":
            rows.append({"arch": cell["arch"], "shape": cell["shape"],
                         "mesh": "2pod" if cell["multi_pod"] else "1pod",
                         "status": "skipped"})
            continue
        t = roofline_terms(cell)
        if t is None:
            rows.append({"arch": cell["arch"], "shape": cell["shape"],
                         "mesh": "2pod" if cell.get("multi_pod") else "1pod",
                         "status": cell.get("status", "?")})
            continue
        rows.append({
            "arch": cell["arch"], "shape": cell["shape"],
            "mesh": "2pod" if cell["multi_pod"] else "1pod",
            "status": "ok", **t,
            "n_active_params": cell["n_active_params"],
            "arg_gb_per_dev": cell["memory"].get(
                "argument_size_in_bytes", 0) / 1e9,
        })
    return rows


def format_table(rows: List[Dict[str, Any]], mesh: str = "1pod") -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'coll_s':>10s} {'bound':>12s} {'useful':>7s} {'RF':>6s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            lines.append(f"{r['arch']:22s} {r['shape']:12s} "
                         f"{'— skipped (sub-quadratic rule)':>40s}")
            continue
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:22s} {r['shape']:12s} {r['status']}")
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant'][:-2]:>12s} {r['useful_ratio']:7.3f} "
            f"{r['roofline_fraction']:6.3f}")
    return "\n".join(lines)


def pick_hillclimb_cells(rows: List[Dict[str, Any]]) -> Dict[str, Any]:
    ok = [r for r in rows if r.get("status") == "ok"
          and r.get("mesh") == "1pod"]
    worst_rf = min(ok, key=lambda r: r["roofline_fraction"])
    coll_bound = [r for r in ok if r["dominant"] == "collective_s"]
    most_coll = max(coll_bound or ok,
                    key=lambda r: r["collective_s"]
                    / max(r["step_time_bound_s"], 1e-12))
    return {"worst_roofline": worst_rf, "most_collective": most_coll}


def main(path: str = "/root/repo/dryrun_results.json"):
    with open(path) as f:
        results = json.load(f)
    rows = build_table(results)
    print("single-pod (16x16 = 256 chips):")
    print(format_table(rows, "1pod"))
    print("\nmulti-pod (2x16x16 = 512 chips):")
    print(format_table(rows, "2pod"))
    picks = pick_hillclimb_cells(rows)
    print("\nhillclimb candidates:")
    for k, r in picks.items():
        print(f"  {k}: {r['arch']} x {r['shape']} "
              f"(RF {r['roofline_fraction']:.3f}, "
              f"dominant {r['dominant']})")
    return rows


if __name__ == "__main__":
    import sys
    main(*sys.argv[1:])

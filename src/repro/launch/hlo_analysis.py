"""Trip-count-aware analysis of partitioned HLO text.

XLA's `compiled.cost_analysis()` counts every called computation ONCE — a
`jax.lax.scan` over 40 layer groups contributes its body a single time, so
FLOPs/bytes are wildly underreported for scanned models (verified
empirically: flops barely change between 2- and 8-layer scans). This module
re-derives the quantities the roofline needs directly from the scheduled
HLO text:

  * computation segmentation + the while-op call graph,
  * loop trip counts (parsed from each while condition's comparison
    constant),
  * per-computation execution multipliers (product of enclosing trips),
  * trip-weighted dot FLOPs  (2 * prod(output dims) * contracted size),
  * trip-weighted collective bytes by kind (shapes are per-partition in the
    SPMD module, so these are per-chip),
  * trip-weighted dot operand/output bytes (an HBM-traffic lower bound used
    as a cross-check on the analytic memory model).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?to_apply=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _shape_elems(dims) * _DTYPE_BYTES.get(dtype, 4)


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """name -> instruction lines. A computation block starts with a line
    '[ENTRY] %name (args...) -> type {' (args may contain nested parens)
    and ends with a lone '}'. Instruction lines inside contain '='."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if cur is None:
            if s.endswith("{") and "->" in s and "=" not in s.split("(")[0]:
                m = _COMP_HDR.match(s)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
        else:
            if s.startswith("}"):
                cur = None
            else:
                comps[cur].append(s)
    return comps


def while_edges(comps: Dict[str, List[str]]) -> List[Tuple[str, str, str]]:
    """(parent_comp, cond_comp, body_comp) for every while instruction."""
    out = []
    for name, lines in comps.items():
        for ln in lines:
            m = _WHILE_RE.search(ln)
            if m:
                out.append((name, m.group(1), m.group(2)))
    return out


def trip_count(cond_lines: List[str]) -> int:
    """Heuristic: the loop bound is the largest integer constant compared in
    the condition computation. Returns 1 when nothing is found."""
    best = 1
    for ln in cond_lines:
        for c in _CONST_RE.findall(ln):
            best = max(best, int(c))
    return best


def multipliers(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """Execution-count multiplier per computation: product of the trip
    counts of enclosing while loops (call graph walked from ENTRY)."""
    # build edges: parent -> (child, weight)
    edges: Dict[str, List[Tuple[str, int]]] = {n: [] for n in comps}
    for parent, cond, body in while_edges(comps):
        t = trip_count(comps.get(cond, []))
        edges[parent].append((body, t))
        edges[parent].append((cond, t + 1))
    for name, lines in comps.items():
        for ln in lines:
            for m in _CALL_RE.finditer(ln):
                edges[name].append((m.group(1), 1))

    mult: Dict[str, int] = {n: 0 for n in comps}
    # the entry computation is conventionally the one nobody calls with a
    # while/call edge; fall back to the one named like the jit function
    called = {c for dst in edges.values() for c, _ in dst}
    roots = [n for n in comps if n not in called]
    stack = [(r, 1) for r in (roots or list(comps)[:1])]
    seen_depth = 0
    while stack:
        seen_depth += 1
        if seen_depth > 100000:
            break
        node, m = stack.pop()
        if m <= mult.get(node, 0):
            continue
        mult[node] = m
        for child, w in edges.get(node, []):
            stack.append((child, m * w))
    return mult


_DEF_RE = re.compile(r"^%?([\w.\-]+)\s*=\s*(\(?[a-z0-9]+\[[0-9,]*\])")
_ARGS_RE = re.compile(r"%([\w.\-]+)")


def build_symbols(hlo: str) -> Dict[str, Tuple[str, str]]:
    """instruction name -> (dtype, dims) of its (first) output shape."""
    table: Dict[str, Tuple[str, str]] = {}
    for line in hlo.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        m = _DEF_RE.match(s)
        if not m:
            continue
        shp = _SHAPE_RE.search(m.group(2))
        if shp:
            table[m.group(1)] = (shp.group(1), shp.group(2))
    return table


def dot_flops_line(line: str, symbols: Dict[str, Tuple[str, str]]) -> int:
    """FLOPs of one dot instruction (2 * out_elems * contracted). Operand
    shapes are resolved through the symbol table (scheduled HLO references
    operands by name)."""
    rhs = line.split("=", 1)[1] if "=" in line else line
    head = rhs.split("dot(", 1)[0]
    out_shapes = _SHAPE_RE.findall(head)
    if not out_shapes:
        return 0
    out_elems = _shape_elems(out_shapes[-1][1])
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    arg_str = rhs.split("dot(", 1)[1].split(")", 1)[0]
    # operand shapes: inline if present, else look up by name
    inline = _SHAPE_RE.findall(arg_str)
    if inline:
        lhs_dims = inline[0][1].split(",") if inline[0][1] else []
    else:
        names = _ARGS_RE.findall(arg_str)
        if not names or names[0] not in symbols:
            return 0
        dims = symbols[names[0]][1]
        lhs_dims = dims.split(",") if dims else []
    contracted = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            i = int(d)
            if i < len(lhs_dims):
                contracted *= int(lhs_dims[i])
    return 2 * out_elems * contracted


def _bf16_provenance(ln: str, defs: Dict[str, str],
                     comps: Dict[str, List[str]]) -> bool:
    """True if a collective's operand is semantically bf16 (the XLA:CPU
    FloatNormalization pass stores all bf16 as f32 and wraps values in
    convert chains, doubling every observed collective byte vs. a TPU
    lowering — verified on qwen2: param fusions contain
    `convert(bf16) -> convert(f32)` chains). We trace the first operand's
    def; a def (or its fusion body) mentioning bf16 marks the value as
    bf16-native."""
    try:
        args = ln.split("(", 1)[1]
        opname = _ARGS_RE.findall(args)[0]
    except (IndexError, ValueError):
        return False
    d = defs.get(opname, "")
    if "bf16" in d:
        return True
    m = re.search(r"calls=%([\w.\-]+)", d)
    if m and m.group(1) in comps:
        return any("bf16" in l for l in comps[m.group(1)])
    return False


def analyze(hlo: str) -> Dict[str, float]:
    comps = split_computations(hlo)
    mult = multipliers(comps)
    symbols = build_symbols(hlo)
    defs: Dict[str, str] = {}
    for line in hlo.splitlines():
        s = line.strip()
        mm = _DEF_RE.match(s) if "=" in s else None
        if mm:
            defs[mm.group(1)] = s
    flops = 0.0
    coll: Dict[str, float] = {k: 0.0 for k in COLL_KINDS}
    coll_tpu: Dict[str, float] = {k: 0.0 for k in COLL_KINDS}
    dot_bytes = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 1) or 1
        for ln in lines:
            if "dot(" in ln:
                f = dot_flops_line(ln, symbols)
                flops += m * f
                rhs = ln.split("=", 1)[1] if "=" in ln else ln
                scale_b = (0.5 if _bf16_provenance(ln, defs, comps)
                           else 1.0)
                for dt, dims in _SHAPE_RE.findall(rhs):
                    b = _shape_bytes(dt, dims)
                    dot_bytes += m * (b * scale_b if dt == "f32" else b)
            elif "=" in ln:
                rhs = ln.split("=", 1)[1]
                head = rhs.split("(", 1)[0].strip()
                token = head.split()[-1] if head else ""
                for k in COLL_KINDS:
                    if token == k or token == k + "-start":
                        nbytes = sum(_shape_bytes(dt, dims)
                                     for dt, dims in _SHAPE_RE.findall(head))
                        coll[k] += m * nbytes
                        # TPU-native accounting: f32 collectives whose
                        # value is bf16-native move 2-byte elements on TPU
                        if ("f32" in head
                                and _bf16_provenance(ln, defs, comps)):
                            coll_tpu[k] += m * nbytes / 2
                        else:
                            coll_tpu[k] += m * nbytes
                        break
    return {
        "dot_flops": flops,
        "dot_bytes": dot_bytes,
        "collective_bytes": {k: v for k, v in coll.items() if v},
        "collective_bytes_tpu": {k: v for k, v in coll_tpu.items() if v},
        "n_computations": len(comps),
        "n_while": len(while_edges(comps)),
    }


_METADATA_RE = re.compile(r'op_name="([^"]*)"')


def top_collectives(hlo: str, k: int = 15):
    """The k largest collectives by trip-weighted bytes, with shapes and
    jax op_name metadata — the §Perf targeting tool."""
    comps = split_computations(hlo)
    mult = multipliers(comps)
    out = []
    for name, lines in comps.items():
        m = mult.get(name, 1) or 1
        for ln in lines:
            if "=" not in ln:
                continue
            rhs = ln.split("=", 1)[1]
            head = rhs.split("(", 1)[0].strip()
            token = head.split()[-1] if head else ""
            kind = None
            for ck in COLL_KINDS:
                if token == ck or token == ck + "-start":
                    kind = ck
                    break
            if kind is None:
                continue
            nbytes = sum(_shape_bytes(dt, dims)
                         for dt, dims in _SHAPE_RE.findall(head))
            meta = _METADATA_RE.search(ln)
            out.append({
                "kind": kind, "bytes": nbytes, "trips": m,
                "total": nbytes * m,
                "shape": head.replace(token, "").strip()[:70],
                "op": (meta.group(1)[-90:] if meta else ""),
            })
    out.sort(key=lambda r: -r["total"])
    return out[:k]

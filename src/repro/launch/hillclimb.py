import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: per-iteration hypothesis -> change -> re-lower ->
re-analyse on the three selected cells. Results accumulate into
hillclimb_results.json; the narrative lands in EXPERIMENTS.md §Perf."""

import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

from repro.launch import dryrun, roofline  # noqa: E402

# (cell, variant-name, variant dict, hypothesis)
EXPERIMENTS = [
    # --- iteration 6 (deepseek): top_collectives after localsort shows
    #     (a) 6x 14GB seq-gathers of the EXPANDED MLA K/V ([B,S,H,128]),
    #     (b) 2x 7GB full-gathers of router probs at the flat top_k.
    #     Changes: head-sharded constraints on expanded K/V + queries
    #     (H=16 divides the model axis), grouped [G,Tl,E] router top_k. --
    ("deepseek-v2-lite-16b", "train_4k", "headshard+localsort",
     {"moe_shards": 16},
     "head-sharded MLA expansion + shard-local router top_k: collective "
     "1.58 -> ~0.5-0.7s"),
    # --- iteration 5: measurement correction. Tracing the f32 gathers to
    #     their producers showed XLA:CPU's FloatNormalization stores every
    #     bf16 value as f32 (convert chains around each use), so observed
    #     collective/dot bytes are 2x what a TPU lowering moves. The
    #     analyzer now halves f32 tensors with bf16 provenance
    #     (collective_bytes_tpu). Re-measure the winners. -----------------
    ("qwen2-72b", "train_4k", "tpu-dtype+dots", {"remat": "dots"},
     "TPU-native byte accounting: collective 14.07 -> ~7s (<= compute "
     "9.58s) => compute-bound, RF ~0.8"),
    ("deepseek-v2-lite-16b", "train_4k", "tpu-dtype+localsort",
     {"moe_shards": 16},
     "TPU-native accounting on the local-sort dispatch: coll 2.58 -> "
     "~1.3-1.6s"),
    ("minicpm3-4b", "decode_32k", "tpu-dtype+absorb", {"mla_absorb": True},
     "TPU-native accounting on absorbed decode: step bound ~halves"),
    # --- iteration 4: the top_collectives dump shows the dominant traffic
    #     is fp32 PARAM shards moving through model/data-axis gathers (the
    #     masters are fp32 at rest and XLA does not reliably sink converts
    #     below the partitioner's gathers). Deterministic fix: bf16 weights
    #     + fp32 masters inside the optimizer state. -----------------------
    ("qwen2-72b", "train_4k", "bf16params",
     {"bf16_params": True},
     "bf16 weights (fp32 masters in opt state): every param gather/reduce "
     "halves => collective 14.07 -> ~7-8s"),
    ("qwen2-72b", "train_4k", "bf16params+dots",
     {"bf16_params": True, "remat": "dots"},
     "stack the compute win: expect compute ~9.6s > collective => "
     "compute-bound, RF ~0.75"),
    ("deepseek-v2-lite-16b", "train_4k", "bf16params+localsort",
     {"bf16_params": True, "moe_shards": 16},
     "bf16 params + local dispatch: collective 2.58 -> ~1.3-1.8s"),
    ("minicpm3-4b", "decode_32k", "bf16serve+absorb",
     {"bf16_params": True, "mla_absorb": True},
     "serve bf16 checkpoint on the absorbed decode: param collectives "
     "halve => step bound 0.020 -> ~0.010s"),
    # --- iteration 3 (after the preferred_element_type code fix): dots now
    #     accumulate fp32 WITHOUT upcasting operands, so the partitioner
    #     moves bf16. Hypothesis: every activation/weight collective around
    #     attention + MLP dots halves => qwen2 coll 14.07 -> ~7s
    #     (compute-bound), dsv2 localsort 2.58 -> ~1.4s. -----------------
    ("qwen2-72b", "train_4k", "pet-bf16", {},
     "preferred_element_type fix: f32 operand upcasts around dots removed "
     "=> collective bytes halve, flips qwen2 to compute-bound"),
    ("qwen2-72b", "train_4k", "pet-bf16+dots", {"remat": "dots"},
     "stack the remat=dots win (compute 11.92->9.58) on the bf16 "
     "collectives"),
    ("deepseek-v2-lite-16b", "train_4k", "pet+localsort",
     {"moe_shards": 16},
     "bf16 dot operands + local dispatch: collective 2.58 -> ~1.4s"),
    ("minicpm3-4b", "decode_32k", "pet+absorb", {"mla_absorb": True},
     "bf16 score dots on the absorbed decode path: collective 0.020 -> "
     "~0.010s"),
    # --- cell A: qwen2-72b x train_4k (largest dense; collective-bound,
    #     baseline compute 11.92s vs coll 14.07s) --------------------------
    ("qwen2-72b", "train_4k", "base", {},
     "baseline: fp32 param gathers + fp32 grad reduce dominate ICI"),
    ("qwen2-72b", "train_4k", "bf16cast", {"cast_params": "bfloat16"},
     "cast fp32 masters to bf16 BEFORE the FSDP all-gather: param-gather "
     "and grad-reduce bytes halve => collective ~14->~7s, flips to "
     "compute-bound"),
    ("qwen2-72b", "train_4k", "bf16cast+dots",
     {"cast_params": "bfloat16", "remat": "dots"},
     "save matmul operands instead of full remat: no fwd recompute in bwd "
     "=> dot_flops -~25%, param re-gathers in bwd disappear (fewer "
     "collectives), at higher activation memory"),
    # --- cell B: deepseek-v2-lite x train_4k (MoE; most collective-bound:
    #     coll 10.66s vs compute 0.55s = 19x) ------------------------------
    ("deepseek-v2-lite-16b", "train_4k", "base", {},
     "baseline: global argsort dispatch emits giant sort collectives"),
    ("deepseek-v2-lite-16b", "train_4k", "localsort", {"moe_shards": 16},
     "shard-local dispatch sort (G=16 aligned with DP): sort/cumsum/"
     "scatter become shard-local; only the token->expert all-to-all "
     "remains => collective drops ~5-10x"),
    ("deepseek-v2-lite-16b", "train_4k", "localsort+bf16",
     {"moe_shards": 16, "cast_params": "bfloat16"},
     "add the bf16 gather cast on top: param/grad collective halves too"),
    # --- cell C: minicpm3-4b x decode_32k (worst roofline fraction; MLA
    #     expansion recomputes K/V from the whole cache every step) --------
    ("minicpm3-4b", "decode_32k", "base", {},
     "baseline: per-step up-projection of the full 32k latent cache "
     "(useful_ratio 0.002)"),
    ("minicpm3-4b", "decode_32k", "absorb", {"mla_absorb": True},
     "weight-absorbed MLA decode: attention runs in the compressed latent "
     "space; per-step flops drop O(S*R*H*(dn+dv)) -> O(S*H*R), cache "
     "traffic one read"),
]


def main(out_path="/root/repo/hillclimb_results.json", only=None):
    results = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["variant"]) for r in results}
    for arch, shape, vname, variant, hypothesis in EXPERIMENTS:
        if only and vname not in only and arch not in only:
            continue
        if (arch, shape, vname) in done:
            continue
        t0 = time.time()
        print(f"\n=== {arch} x {shape} [{vname}] ===")
        print(f"hypothesis: {hypothesis}")
        try:
            cell = dryrun.run_cell(arch, shape, multi_pod=False,
                                   variant=variant)
            terms = roofline.roofline_terms(cell)
            rec = {"arch": arch, "shape": shape, "variant": vname,
                   "hypothesis": hypothesis, "variant_cfg": variant,
                   "cell": cell, "terms": terms,
                   "wall_s": round(time.time() - t0, 1)}
            print(f"  compute {terms['compute_s']:.3f}s | memory "
                  f"{terms['memory_s']:.3f}s | collective "
                  f"{terms['collective_s']:.3f}s | bound "
                  f"{terms['dominant']} | RF {terms['roofline_fraction']:.3f}"
                  f" | useful {terms['useful_ratio']:.3f}")
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "variant": vname,
                   "hypothesis": hypothesis, "error": str(e)[:1000]}
            print(f"  FAILED: {e}")
        results.append(rec)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main(only=set(sys.argv[1:]) or None)

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, proving the distribution config is coherent, and
extract the memory/cost/collective analyses the roofline consumes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
      --out dryrun_results.json

NOTE: the XLA_FLAGS line above MUST run before any other import (jax locks
the device count on first init)."""

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro import configs                      # noqa: E402
from repro.launch import mesh as meshlib       # noqa: E402
from repro.launch.shapes import SHAPES, applicable  # noqa: E402
from repro.models import lm                    # noqa: E402
from repro.parallel import sharding            # noqa: E402
from repro.train import optimizer as optim     # noqa: E402
from repro.train import train_step as ts       # noqa: E402


def _abs_tree(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def abstract_params(cfg):
    return jax.eval_shape(
        lambda k: lm.lm_init(k, cfg), jax.random.PRNGKey(0))


def input_specs(arch: str, shape_name: str, cfg=None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell
    (weak-type-correct, shardable, no device allocation)."""
    cfg = cfg or configs.get_config(arch)
    spec = SHAPES[shape_name]
    B, S = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    out: Dict[str, Any] = {"kind": spec.kind}

    if spec.kind == "train":
        n_pre = cfg.n_prefix_embeds
        s_txt = S - n_pre
        tok_shape = (B, cfg.n_codebooks, s_txt) if cfg.n_codebooks > 1 \
            else (B, s_txt)
        batch = {
            "tokens": jax.ShapeDtypeStruct(tok_shape, i32),
            "labels": jax.ShapeDtypeStruct(tok_shape, i32),
        }
        if n_pre:
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, n_pre, cfg.d_model), jnp.bfloat16)
        out["batch"] = batch
        return out

    if spec.kind == "prefill":
        n_pre = cfg.n_prefix_embeds
        s_txt = S - n_pre
        tok_shape = (B, cfg.n_codebooks, s_txt) if cfg.n_codebooks > 1 \
            else (B, s_txt)
        out["tokens"] = jax.ShapeDtypeStruct(tok_shape, i32)
        if n_pre:
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, n_pre, cfg.d_model), jnp.bfloat16)
        out["caches"] = _abs_tree(
            jax.eval_shape(lambda: lm.init_caches(cfg, B, S)))
        return out

    # decode: one new token against a cache of size S
    tok_shape = (B, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B,)
    out["token"] = jax.ShapeDtypeStruct(tok_shape, i32)
    out["pos"] = S - 1
    out["kv_valid"] = jax.ShapeDtypeStruct((B,), i32)
    out["caches"] = _abs_tree(
        jax.eval_shape(lambda: lm.init_caches(cfg, B, S)))
    return out


# ---------------------------------------------------------------------------
# collective-bytes extraction from the (possibly partitioned) HLO text
# ---------------------------------------------------------------------------
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _bytes_of_shape(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of *output* shape bytes per collective kind, parsed from the
    SPMD-partitioned HLO (shapes are already per-partition). HLO line
    format: `%name = TYPE[dims]{layout} all-gather(%args...)`. `-start`
    variants are counted; `-done` ops (which repeat the shape) are not."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        head = rhs.split("(", 1)[0]           # "TYPE[dims]{l} opname"
        kind = None
        for k in _COLL_KINDS:
            token = head.strip().split()[-1] if head.strip() else ""
            if token == k or token == k + "-start":
                kind = k
                break
        if kind is None:
            continue
        shapes = _SHAPE_RE.findall(head)
        nbytes = sum(_bytes_of_shape(dt, dims) for dt, dims in shapes)
        out[kind] = out.get(kind, 0) + nbytes
    return out


def _summarize_memory(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def production_variant(arch: str, shape_name: str, cfg) -> dict:
    """The per-arch 'optimized' profile: every §Perf-confirmed win that
    generalized (EXPERIMENTS.md §Perf extension table).
      * MoE archs: shard-local dispatch (moe_shards=16, 4-6x less coll)
      * train cells: dots-remat (no fwd recompute; useful ~0.95)
      * mamba2: sequence parallelism off (residual gathers dominate at
        d_model=1536)
      * serve cells: bf16 checkpoint; MLA archs decode weight-absorbed
      * train: bf16 weights + fp32 masters in optimizer state
    """
    from repro.launch.shapes import SHAPES
    v: dict = {}
    kind = SHAPES[shape_name].kind
    if cfg.mlp_type == "moe" and kind != "decode":
        # decode batches are tiny (8 tokens/group): shard-local dispatch
        # pads min-capacity buffers and REGRESSES 3-20x — measured, so
        # decode keeps the global sort.
        v["moe_shards"] = 16
    if kind == "train":
        if arch != "recurrentgemma-9b":   # dots-remat: -2% there, + else
            v["remat"] = "dots"
        v["bf16_params"] = True
        if arch == "mamba2-780m":
            v["sequence_parallel"] = False
    else:
        v["bf16_params"] = True
        if cfg.attn_impl == "mla" and kind == "decode":
            v["mla_absorb"] = True
    return v


def apply_variant(cfg, variant: Optional[dict]):
    """Apply a §Perf variant to the model config. Keys:
    remat, moe_shards, mla_absorb, use_pallas (model-level);
    cast_params, sequence_parallel (train-step level, consumed by
    lower_cell)."""
    import dataclasses
    if not variant:
        return cfg
    upd = {}
    for k in ("remat", "mla_absorb", "use_pallas", "shard_strategy"):
        if k in variant:
            upd[k] = variant[k]
    if "moe_shards" in variant and cfg.moe is not None:
        upd["moe"] = dataclasses.replace(
            cfg.moe, n_dispatch_shards=variant["moe_shards"])
    return dataclasses.replace(cfg, **upd) if upd else cfg


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               cfg=None, opt_override: Optional[dict] = None,
               variant: Optional[dict] = None):
    """Build + lower the cell's step function. Returns (lowered, meta)."""
    cfg = cfg or configs.get_config(arch)
    cfg = apply_variant(cfg, variant)
    variant = variant or {}
    skip = applicable(cfg, shape_name)
    if skip:
        raise ValueError(f"cell skipped: {skip}")
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    spec = input_specs(arch, shape_name, cfg)
    params_abs = abstract_params(cfg)
    if variant.get("bf16_params") and spec["kind"] != "train":
        # serving from a bf16 checkpoint (production inference default)
        params_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32
                else s.dtype), params_abs)

    if spec["kind"] == "train":
        if variant.get("bf16_params"):
            params_abs = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape, jnp.bfloat16 if s.dtype == jnp.float32
                    else s.dtype), params_abs)
            opt_abs = jax.eval_shape(
                lambda p: optim.adamw_init(p, keep_master=True), params_abs)
        else:
            opt_abs = jax.eval_shape(optim.adamw_init, params_abs)
        opt_cfg = optim.AdamWConfig(**(opt_override or {}))
        # sequence parallelism on by default: per-layer saved residuals
        # otherwise replicate the seq dim across "model" (16x activation
        # memory; measured 40GB/dev on phi3/train_4k without SP).
        _, jit_builder = ts.make_train_step(
            cfg, opt_cfg, mesh,
            sequence_parallel=variant.get("sequence_parallel", True),
            cast_params=variant.get("cast_params"))
        jitted = jit_builder(params_abs, opt_abs, spec["batch"])
        with mesh:
            lowered = jitted.lower(params_abs, opt_abs, spec["batch"])
    elif spec["kind"] == "prefill":
        _, jit_builder = ts.make_serve_step(cfg, mesh, kind="prefill")
        jitted = jit_builder(params_abs, spec["caches"], spec["tokens"],
                             prefix_abs=spec.get("prefix_embeds"))
        with mesh:
            if "prefix_embeds" in spec:
                lowered = jitted.lower(params_abs, spec["tokens"],
                                       spec["caches"],
                                       spec["prefix_embeds"])
            else:
                lowered = jitted.lower(params_abs, spec["tokens"],
                                       spec["caches"])
    else:
        _, jit_builder = ts.make_serve_step(cfg, mesh, kind="decode")
        jitted = jit_builder(params_abs, spec["caches"], spec["token"])
        with mesh:
            lowered = jitted.lower(params_abs, spec["token"], spec["pos"],
                                   spec["caches"], spec["kv_valid"])
    meta = {"arch": arch, "shape": shape_name, "kind": spec["kind"],
            "multi_pod": multi_pod,
            "n_devices": int(np.prod(list(mesh.shape.values())))}
    return lowered, meta


def model_flops_for_cell(cfg, shape_name: str) -> Dict[str, float]:
    """Analytic MODEL_FLOPS: 6*N_active*tokens (train) / 2*N_active*tokens
    (inference), the paper-standard accounting used for the §Roofline
    useful-compute ratio."""
    from repro.launch.shapes import SHAPES
    spec = SHAPES[shape_name]
    params_abs = abstract_params(cfg)
    n_total = lm.param_count(params_abs)
    n_active = lm.active_param_count(cfg, params_abs)
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        mf = 6.0 * n_active * tokens
    elif spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        mf = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        mf = 2.0 * n_active * spec.global_batch
    return {"n_params": float(n_total), "n_active_params": float(n_active),
            "model_flops_global": mf}


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             verbose: bool = True, deep_analysis: bool = True,
             variant: Optional[dict] = None) -> Dict[str, Any]:
    t0 = time.time()
    cfg = apply_variant(configs.get_config(arch), variant)
    skip = applicable(cfg, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": skip}
    lowered, meta = lower_cell(arch, shape_name, multi_pod, cfg=cfg,
                               variant=variant)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    res: Dict[str, Any] = dict(meta)
    res["status"] = "ok"
    res["lower_s"] = round(t_lower, 1)
    res["compile_s"] = round(t_compile, 1)
    res["xla_flops"] = float(cost.get("flops", -1.0))
    res["xla_bytes"] = float(cost.get("bytes accessed", -1.0))
    res["memory"] = _summarize_memory(compiled)
    res.update(model_flops_for_cell(cfg, shape_name))
    if deep_analysis:
        from repro.launch import hlo_analysis
        h = hlo_analysis.analyze(compiled.as_text())
        res["dot_flops_per_dev"] = h["dot_flops"]
        res["dot_bytes_per_dev"] = h["dot_bytes"]
        res["collective_bytes"] = h["collective_bytes"]
        res["collective_bytes_tpu"] = h["collective_bytes_tpu"]
        res["n_while"] = h["n_while"]
    else:
        res["collective_bytes"] = collective_bytes(compiled.as_text())
    if verbose:
        ma = res["memory"]
        per_dev = (ma.get("argument_size_in_bytes", 0)
                   + ma.get("temp_size_in_bytes", 0)) / 1e9
        print(f"[{arch} x {shape_name} x "
              f"{'2pod' if multi_pod else '1pod'}] ok "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
              f"dotflops/dev {res.get('dot_flops_per_dev', -1):.3g} "
              f"mem/dev {per_dev:.2f}GB "
              f"coll {sum(res['collective_bytes'].values())/1e9:.3f}GB")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--profile", default="baseline",
                    choices=["baseline", "optimized"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells_to_run = []
    archs = configs.ARCH_IDS if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells_to_run.append((a, s, mp))

    results = []
    for a, s, mp in cells_to_run:
        try:
            variant = None
            if args.profile == "optimized":
                variant = production_variant(a, s, configs.get_config(a))
            res = run_cell(a, s, multi_pod=mp, variant=variant)
            res["profile"] = args.profile
            results.append(res)
        except Exception as e:  # a failing cell is a bug; record it
            print(f"[{a} x {s} x {'2pod' if mp else '1pod'}] FAILED: {e}")
            results.append({"arch": a, "shape": s, "multi_pod": mp,
                            "status": "failed", "error": str(e)[:2000]})
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "failed" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_fail} FAILED of {len(results)}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())

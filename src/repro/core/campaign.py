"""Crash-safe sweep campaigns over `simulator.run_batch`.

`run_batch` made every (mix x rate) grid a sharded, streaming,
multi-minute campaign — but a single host-side failure (an OOM in one
chunk, a stalled `lax.while_loop`, a SIGKILL'd process) used to throw
away every completed chunk. This layer wraps the sweep engine with the
resilience a long campaign needs (DS3 / CEDR both stress this for DSSoC
runtime studies):

  * **chunking** — the scenario axis is cut into the engine's own
    fixed-shape chunks (same rounding and padding as `run_batch`, so
    chunk boundaries and per-scenario results are bit-identical to one
    uninterrupted sweep);
  * **checkpointing** — each completed chunk is written atomically
    (temp file + `os.replace`, the portable `os.rename`) into a campaign
    directory keyed by a content hash of the scenario spec (workloads,
    params, tree, thresholds, fault plan, mode), with a `manifest.json`
    describing the layout. A killed campaign re-run with the same spec
    resumes from the completed chunks and returns byte-identical results;
  * **watchdog** — each chunk dispatch runs under a host-side wall-clock
    timeout (`watchdog_s`), and optionally a device-side `step_budget`
    that caps the simulator's event loop so a pathological chunk
    terminates on its own (lanes that hit it report
    `SimResult.stall_reason == STALL_BUDGET`);
  * **retry** — chunk failures (XLA RESOURCE_EXHAUSTED, watchdog expiry,
    stall-budget trips) are retried with exponential backoff + jitter.
    OOM additionally halves the chunk's batch size (down to one scenario
    per device) before giving up; stall-budget trips escalate the step
    budget. Unrecognized exceptions propagate immediately — they are
    bugs, not infrastructure weather;
  * **length-aware packing** — the batched engine's while loop has a
    scalar cond (`any(running)`), so a chunk runs until its *longest*
    scenario retires and every other lane spins masked. Scenarios are
    therefore ordered by a cheap predicted event count
    (`3 * n_tasks + n_insts`, the engine's own `max_iters` shape) so
    chunk-mates retire together, descending so the padded tail chunk
    replays the *cheapest* scenario. The permutation is recorded in the
    manifest, validated on resume, and results are unscattered back to
    grid order before return — bit-identical to an unpacked sweep.
    `pack=False` or `REPRO_BENCH_PACK=0` opts out; per-sweep occupancy
    (lane-iterations retired vs. allocated) lands in the stats.

Checkpoint format (`<dir>/<spec_hash[:16]>-b<B>/`):

  * `manifest.json` — `{version, spec_hash, mode, n_scenarios,
    chunk_size, n_chunks, fields, perm, jax, numpy}`; written atomically
    once. Chunks are stored in packed order; `perm` maps packed position
    -> grid index.
  * `chunk_00000.npz` .. — one file per completed chunk; every
    `SimResult` field under `r_<name>` with leading dim `chunk_size`,
    plus a `meta` JSON blob (wall time, attempts, retries, shrinks).
    Existence of the (atomically renamed) file is the completion marker;
    unreadable or shape-mismatched files are deleted and recomputed.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import hashlib
import json
import os
import time
from typing import Callable, NamedTuple, Sequence

import numpy as np

from repro.core import faults as flt, simulator as sim
from repro.core.workloads import FlatWorkload, stack_workloads

MANIFEST_NAME = "manifest.json"
FORMAT_VERSION = 2    # v2: length-aware packing (chunks in packed order)


class CampaignError(RuntimeError):
    """A chunk exhausted its retry budget (or the spec/manifest clash)."""


class ChunkTimeout(CampaignError):
    """A chunk dispatch exceeded the host-side watchdog."""


class ChunkStalled(CampaignError):
    """A chunk came back with lanes that hit the device-side step budget."""


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Backoff/retry knobs for failed chunks.

    `max_retries` bounds retries *per chunk* (so `max_retries + 1` total
    attempts). Backoff for retry `k` is
    `min(backoff_max_s, backoff_base_s * backoff_factor**k)` stretched by
    up to `jitter_frac` of itself (seeded, so campaigns are reproducible).
    `budget_escalation` multiplies the step budget after a stall trip;
    `shrink_floor` is the smallest per-device batch OOM-halving may reach.
    """

    max_retries: int = 3
    backoff_base_s: float = 0.5
    backoff_factor: float = 2.0
    backoff_max_s: float = 30.0
    jitter_frac: float = 0.25
    seed: int = 0
    budget_escalation: int = 8
    shrink_floor: int = 1

    def backoff_s(self, attempt: int, rng: np.random.RandomState) -> float:
        base = min(self.backoff_max_s,
                   self.backoff_base_s * self.backoff_factor ** attempt)
        return base * (1.0 + self.jitter_frac * float(rng.uniform()))


@dataclasses.dataclass
class CampaignStats:
    """Counters surfaced in `benchmarks.run --json` (see `as_dict`)."""

    n_scenarios: int = 0
    n_chunks: int = 0
    chunks_reused: int = 0      # loaded from a checkpoint, not recomputed
    chunks_computed: int = 0
    retries: int = 0            # chunk attempts after the first
    timeouts: int = 0           # watchdog expiries
    oom_events: int = 0         # RESOURCE_EXHAUSTED catches
    shrinks: int = 0            # batch-size halvings
    stall_trips: int = 0        # step-budget exhaustions
    chunk_wall_s: list = dataclasses.field(default_factory=list)
    wall_s: float = 0.0
    packed: bool = False        # length-aware chunk packing in effect
    # lane-occupancy telemetry, summed over computed (not reused) chunks:
    # `lane_trips` = lane-iterations allocated (S x while-loop trips per
    # shard), `active_trips` = those on which the lane was still live,
    # `retired_events` = simulator events actually retired
    lane_trips: int = 0
    active_trips: int = 0
    retired_events: int = 0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["occupancy"] = (self.active_trips / self.lane_trips
                          if self.lane_trips else None)
        return d


class CampaignResult(NamedTuple):
    result: sim.SimResult   # leading [S] axis, host numpy
    stats: dict             # CampaignStats.as_dict()


# ---------------------------------------------------------------------------
# spec hashing + atomic files
# ---------------------------------------------------------------------------
def _hash_update(h, tag: str, value) -> None:
    if value is None:
        h.update(f"{tag}:none".encode())
        return
    arr = np.ascontiguousarray(np.asarray(value))
    h.update(f"{tag}:{arr.dtype.str}:{arr.shape}".encode())
    h.update(arr.tobytes())


def spec_hash(mode: int, stacked: FlatWorkload, params, tree,
              rate_threshold, plan) -> str:
    """Content hash of everything that determines per-scenario results.

    Deliberately excludes chunk size, device count and retry/watchdog
    knobs: results are invariant to them, so checkpoints written under
    one host configuration remain addressable (the chunk *layout* is
    keyed separately, by the `-b<B>` directory suffix).
    """
    h = hashlib.sha256()
    h.update(f"campaign-v{FORMAT_VERSION}:mode={int(mode)}".encode())
    for name, field in zip(FlatWorkload._fields, stacked):
        _hash_update(h, f"wl.{name}", field)
    for name, field in zip(type(params)._fields, params):
        _hash_update(h, f"p.{name}", field)
    for name, field in zip(type(tree)._fields, tree):
        _hash_update(h, f"t.{name}", field)
    _hash_update(h, "rate_threshold", rate_threshold)
    if plan is None:
        _hash_update(h, "plan", None)
    else:
        for name, field in zip(flt.FaultPlan._fields, plan):
            _hash_update(h, f"f.{name}", field)
    return h.hexdigest()


def atomic_write_json(path: str, obj, default=repr) -> None:
    """Write JSON via a temp file + `os.replace` so a crash mid-dump never
    leaves a truncated file behind (also used by `benchmarks.run --json`)."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=2, default=default)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _atomic_savez(path: str, **arrays) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _chunk_path(cdir: str, idx: int) -> str:
    return os.path.join(cdir, f"chunk_{idx:05d}.npz")


def _save_chunk(path: str, res: sim.SimResult, meta: dict) -> None:
    arrays = {f"r_{name}": np.asarray(field)
              for name, field in zip(sim.SimResult._fields, res)}
    arrays["meta"] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8)
    _atomic_savez(path, **arrays)


def _load_chunk(path: str, chunk_size: int):
    """Load a checkpointed chunk; corrupt/stale files are deleted and
    `None` is returned so the chunk is recomputed."""
    try:
        with np.load(path) as z:
            fields = [z[f"r_{name}"] for name in sim.SimResult._fields]
    except Exception:
        fields = None
    if fields is None or any(f.shape[:1] != (chunk_size,) for f in fields):
        try:
            os.remove(path)
        except OSError:
            pass
        return None
    return sim.SimResult(*fields)


def _open_campaign_dir(root: str, manifest: dict) -> str:
    """Create/validate the per-spec campaign directory under `root`."""
    cdir = os.path.join(
        root, f"{manifest['spec_hash'][:16]}-b{manifest['chunk_size']}")
    os.makedirs(cdir, exist_ok=True)
    mpath = os.path.join(cdir, MANIFEST_NAME)
    if os.path.exists(mpath):
        try:
            with open(mpath) as f:
                old = json.load(f)
        except (OSError, ValueError):
            old = None
        keys = ("version", "spec_hash", "mode", "n_scenarios",
                "chunk_size", "n_chunks", "fields", "perm")
        if old is not None and all(old.get(k) == manifest[k] for k in keys):
            return cdir
        # unreadable or stale manifest (e.g. a checkpoint format bump):
        # drop the old chunks — their layout can no longer be trusted
        for name in os.listdir(cdir):
            if name.startswith("chunk_") or name == MANIFEST_NAME:
                try:
                    os.remove(os.path.join(cdir, name))
                except OSError:
                    pass
    atomic_write_json(mpath, manifest)
    return cdir


# ---------------------------------------------------------------------------
# failure classification + watchdog
# ---------------------------------------------------------------------------
def _is_oom(exc: BaseException) -> bool:
    msg = str(exc).lower()
    return ("resource_exhausted" in msg or "out of memory" in msg
            or "outofmemory" in msg)


def _call_with_watchdog(fn: Callable, timeout_s: float | None):
    """Run `fn` under a wall-clock timeout.

    The computation runs in a worker thread; on expiry a `ChunkTimeout`
    is raised and the thread is abandoned (a JAX dispatch cannot be
    cancelled from the host — the device-side `step_budget` exists so
    the abandoned work still terminates instead of pinning the device)."""
    if timeout_s is None:
        return fn()
    ex = concurrent.futures.ThreadPoolExecutor(max_workers=1)
    fut = ex.submit(fn)
    try:
        return fut.result(timeout=timeout_s)
    except concurrent.futures.TimeoutError:
        raise ChunkTimeout(
            f"chunk exceeded the {timeout_s:g}s watchdog") from None
    finally:
        ex.shutdown(wait=False)


# Module-level so tests can monkeypatch it to inject OOMs / hangs / crashes.
def _compute_chunk(mode: int, part: FlatWorkload, params, tree,
                   rate_threshold, plan, batch: int, devices: tuple,
                   step_budget: int | None,
                   telemetry: list | None = None) -> sim.SimResult:
    """One fixed-shape `run_batch` dispatch, fetched to host numpy."""
    res = sim.run_batch(mode, part, params, tree=tree,
                        rate_threshold=rate_threshold, plan=plan,
                        batch_size=batch, devices=list(devices),
                        step_budget=step_budget, telemetry=telemetry)
    return sim.SimResult(*[np.asarray(f) for f in res])


def _resolve_pack(pack: bool | None) -> bool:
    """`pack=` knob, falling back to `REPRO_BENCH_PACK` (default on)."""
    if pack is not None:
        return bool(pack)
    raw = os.environ.get("REPRO_BENCH_PACK", "1").strip().lower()
    return raw not in ("0", "off", "no", "false")


def predicted_events(stacked: FlatWorkload) -> np.ndarray:
    """[S] cheap per-scenario event-count predictor: `3 * n_tasks +
    n_insts`, the exact shape of the engine's `max_iters` bound (each
    task is pushed, decided, and completed once; each instance arrives
    once). Fault retries add a data-dependent tail the predictor ignores
    — ordering only needs to be correlated with the true length."""
    return (3 * np.asarray(stacked.n_tasks, np.int64)
            + np.asarray(stacked.n_insts, np.int64))


# ---------------------------------------------------------------------------
# the campaign runner
# ---------------------------------------------------------------------------
def _shrink_batch(b: int, n_dev: int, floor: int) -> int:
    """Halve a chunk batch, keeping it a positive device multiple."""
    lo = max(floor, 1) * n_dev
    return max(lo, (b // 2) // n_dev * n_dev or lo)


def run_campaign(mode: int, wls, params=None, tree=None,
                 rate_threshold=1e9,
                 batch_size: int | None = None,
                 plan=None,
                 devices=None,
                 checkpoint_dir: str | None = None,
                 resume: bool = True,
                 watchdog_s: float | None = None,
                 step_budget: int | None = None,
                 retry: RetryPolicy | None = None,
                 chunk_delay_s: float = 0.0,
                 pack: bool | None = None) -> CampaignResult:
    """Crash-safe equivalent of `sim.run_batch` (same sweep arguments).

    Campaign knobs: `checkpoint_dir` roots the chunk checkpoints (None
    disables checkpointing; `resume=False` recomputes existing chunks),
    `watchdog_s` / `step_budget` bound each chunk in wall clock / device
    steps, `retry` configures backoff (see `RetryPolicy`),
    `chunk_delay_s` sleeps between chunks (throttle; the kill-and-resume
    smoke test uses it to widen the SIGKILL window), and `pack` orders
    scenarios into chunks by predicted event count so fixed-shape chunks
    retire together (default: `REPRO_BENCH_PACK`, on) — results are
    unscattered back to input order before return, so packing never
    changes what a caller sees.

    Returns `(result, stats)`: `result` is bit-identical to one
    uninterrupted `run_batch` call over the same scenarios — whether the
    chunks were computed now, loaded from checkpoints, or both, packed
    or not.
    """
    params = params or sim.make_params()
    tree = tree if tree is not None else sim.always_fast_tree()
    retry = retry or RetryPolicy()
    stacked = wls if isinstance(wls, FlatWorkload) else stack_workloads(wls)
    stacked = FlatWorkload(*[np.asarray(f) for f in stacked])
    n = int(stacked.task_type.shape[0])
    if plan is not None:
        plan = flt.validate_plan(
            plan, n_pes=np.asarray(params.pe_cluster).shape[0],
            n_clusters=np.asarray(params.cluster_pe_mask).shape[0])
        plan = flt.FaultPlan(*[np.asarray(f) for f in plan])
    rate_threshold = np.asarray(rate_threshold, np.float32)

    devs = sim._resolve_devices(devices)
    D = len(devs)
    if batch_size is not None and batch_size <= 0:
        raise ValueError(f"batch_size must be positive, got {batch_size}")
    # identical chunk geometry to run_batch: clamp, round up to a device
    # multiple, pad the ragged tail by replaying the last real scenario
    B = n if batch_size is None else min(batch_size, n)
    B = -(-B // D) * D
    n_pad = -(-n // B) * B
    n_chunks = n_pad // B
    # length-aware packing: schedule scenarios in descending predicted
    # length so each fixed-shape chunk's lanes retire together and the
    # padded tail chunk (which replays its last scenario) is the cheapest.
    # Packing only reorders *which* scenarios share a chunk; per-scenario
    # results are bit-exact, and the stable sort keeps the layout (and
    # hence checkpoint addressing) deterministic for resume.
    do_pack = _resolve_pack(pack) and n_chunks > 1
    if do_pack:
        perm = np.argsort(-predicted_events(stacked), kind="stable")
    else:
        perm = np.arange(n)
    # schedule order incl. the replayed-pad tail (grid indices per lane)
    sched = np.concatenate([perm, np.full(n_pad - n, perm[-1] if n else 0,
                                          dtype=perm.dtype)])

    tree_np = type(tree)(*[np.asarray(f) for f in tree])
    tree_b = tree_np.feat.ndim == 2
    thr_b = rate_threshold.ndim >= 1
    plan_b = plan is not None and flt.is_batched(plan)
    if plan_b and plan.pe_fail_at.shape[0] != n:
        raise ValueError(
            f"run_campaign: batched plan has {plan.pe_fail_at.shape[0]} "
            f"scenarios but the workload has {n}")

    def make_args(ids: np.ndarray):
        part = FlatWorkload(*[f[ids] for f in stacked])
        t = type(tree)(*[f[ids] for f in tree_np]) if tree_b else tree
        rt = rate_threshold[ids] if thr_b else rate_threshold
        pl = flt.FaultPlan(*[f[ids] for f in plan]) if plan_b else plan
        return part, t, rt, pl

    stats = CampaignStats(n_scenarios=n, n_chunks=n_chunks,
                          packed=bool(do_pack))
    cdir = None
    if checkpoint_dir:
        h = spec_hash(mode, stacked, params, tree_np, rate_threshold, plan)
        import jax
        manifest = {
            "version": FORMAT_VERSION, "spec_hash": h, "mode": int(mode),
            "n_scenarios": n, "chunk_size": B, "n_chunks": n_chunks,
            "fields": list(sim.SimResult._fields),
            "perm": [int(i) for i in perm],
            "jax": jax.__version__, "numpy": np.__version__,
        }
        cdir = _open_campaign_dir(checkpoint_dir, manifest)

    rng = np.random.RandomState(retry.seed)
    t_start = time.perf_counter()
    chunk_results = []
    for ci in range(n_chunks):
        path = _chunk_path(cdir, ci) if cdir else None
        res = None
        if path and resume and os.path.exists(path):
            res = _load_chunk(path, B)
            if res is not None:
                stats.chunks_reused += 1
                stats.chunk_wall_s.append(0.0)
        if res is None:
            t0 = time.perf_counter()
            ids = sched[ci * B:(ci + 1) * B]
            res, meta = _run_chunk_with_retries(
                mode, make_args, ids, params, B, devs, watchdog_s,
                step_budget, retry, rng, stats, label=f"chunk {ci}")
            wall = time.perf_counter() - t0
            meta["wall_s"] = round(wall, 4)
            stats.chunk_wall_s.append(round(wall, 4))
            stats.chunks_computed += 1
            if path:
                _save_chunk(path, res, meta)
        chunk_results.append(res)
        if chunk_delay_s:
            time.sleep(chunk_delay_s)
    # chunks are in schedule (packed) order: unscatter back to input order
    # (`packed[i]` is scenario `perm[i]`, so row j comes from `inv[j]`)
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n)
    out = sim.SimResult(*[
        np.concatenate(fields, axis=0)[:n][inv]
        for fields in zip(*chunk_results)
    ])
    stats.wall_s = round(time.perf_counter() - t_start, 4)
    return CampaignResult(out, stats.as_dict())


def _run_chunk_with_retries(mode, make_args, chunk_ids, params, B, devs,
                            watchdog_s, step_budget, retry: RetryPolicy,
                            rng, stats: CampaignStats,
                            label: str) -> tuple:
    """Attempt one chunk until it succeeds or the retry budget runs out.

    Mutable per-chunk state across attempts: `b` (the sub-batch size,
    halved on OOM) and `budget` (the step budget, escalated on stall
    trips). The returned result always covers the full `B` scenarios."""
    D = len(devs)
    b = B
    budget = step_budget
    meta = {"attempts": 0, "retries": 0, "shrinks": 0, "timeouts": 0,
            "stall_trips": 0, "final_batch": b, "final_step_budget": budget}
    failure = None
    for attempt in range(retry.max_retries + 1):
        meta["attempts"] = attempt + 1
        if attempt:
            stats.retries += 1
            meta["retries"] += 1
            delay = retry.backoff_s(attempt - 1, rng)
            if delay > 0:
                print(f"# campaign [{label}]: retry {attempt}/"
                      f"{retry.max_retries} after {failure}; backing off "
                      f"{delay:.2f}s (batch {b}, step budget {budget})")
                time.sleep(delay)
        # fresh per attempt so a failed attempt's partial sub-dispatches
        # never pollute the occupancy counters
        tel = []
        try:
            res = _attempt_chunk(mode, make_args, chunk_ids, params, B, b,
                                 devs, budget, watchdog_s, telemetry=tel)
        except ChunkTimeout as e:
            stats.timeouts += 1
            meta["timeouts"] += 1
            failure = e
            continue
        except Exception as e:  # noqa: BLE001 — classified below
            if not _is_oom(e):
                raise
            stats.oom_events += 1
            failure = e
            if b > retry.shrink_floor * D:
                b = _shrink_batch(b, D, retry.shrink_floor)
                stats.shrinks += 1
                meta["shrinks"] += 1
                meta["final_batch"] = b
            continue
        if budget is not None and \
                (np.asarray(res.stall_reason) == sim.STALL_BUDGET).any():
            stats.stall_trips += 1
            meta["stall_trips"] += 1
            failure = ChunkStalled(
                f"lanes hit the {budget}-step budget")
            budget = budget * retry.budget_escalation
            meta["final_step_budget"] = budget
            continue
        for rec in tel:
            stats.lane_trips += rec["lane_trips"]
            stats.active_trips += rec["active_trips"]
            stats.retired_events += rec["events"]
        return res, meta
    raise CampaignError(
        f"{label}: gave up after {retry.max_retries + 1} attempts "
        f"(last failure: {failure})") from failure


def _attempt_chunk(mode, make_args, chunk_ids, params, B, b, devs,
                   budget, watchdog_s,
                   telemetry: list | None = None) -> sim.SimResult:
    """One attempt at a chunk, possibly as `ceil(B/b)` sub-dispatches
    when OOM shrank the batch below the chunk size. Sub-chunks are padded
    the same way as the campaign pads the global tail (replay the last
    scenario, slice the pad off), so shrinking never changes results."""
    if b >= B:
        part, t, rt, pl = make_args(chunk_ids)
        return _call_with_watchdog(
            lambda: _compute_chunk(mode, part, params, t, rt, pl, B, devs,
                                   budget, telemetry=telemetry),
            watchdog_s)
    n_sub = -(-B // b) * b
    sub_idx = np.minimum(np.arange(n_sub), B - 1)
    subs = []
    for lo in range(0, n_sub, b):
        ids = chunk_ids[sub_idx[lo:lo + b]]
        part, t, rt, pl = make_args(ids)
        subs.append(_call_with_watchdog(
            lambda part=part, t=t, rt=rt, pl=pl: _compute_chunk(
                mode, part, params, t, rt, pl, b, devs, budget,
                telemetry=telemetry),
            watchdog_s))
    return sim.SimResult(*[
        np.concatenate(fields, axis=0)[:B] for fields in zip(*subs)
    ])

"""Independent pure-Python reference simulator (differential oracle).

Implements the same event semantics as the jittable simulator —
completions due, then arrivals due, then one scheduling decision, else
advance — with plain dicts and floats. Used by tests/test_differential.py
to cross-check the lax.while_loop implementation: two independently-written
simulators agreeing on per-task finish times is strong evidence neither
mis-encodes the model.

Tie-breaking contracts replicated exactly:
  * completions: earliest (finish, task-id),
  * LUT: FIFO head task; earliest-free PE within the LUT cluster
    (lowest PE id on ties),
  * ETF: scan ready slots in FIFO order x PEs ascending; strict '<' keeps
    the first minimum (matches argmin over the flattened [R, P] matrix).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import soc
from repro.core.simulator import (MODE_ETF, MODE_ETF_IDEAL, MODE_LUT)
from repro.core.workloads import FlatWorkload


def simulate_ref(mode: int, wl: FlatWorkload,
                 cfg: soc.SoCConfig | None = None) -> Dict:
    cfg = cfg or soc.default_soc()
    exec_pe = cfg.exec_on_pe()                    # [types, P]
    pe_cluster = cfg.pe_cluster
    pe_power = cfg.cluster_power[pe_cluster]
    n_tasks = int(wl.n_tasks)
    n_inst = int(wl.n_insts)
    P = cfg.n_pes

    pred_rem = wl.n_preds.astype(int).copy()
    finish = np.full(n_tasks, np.inf)
    start = np.full(n_tasks, np.inf)
    pe_of = np.full(n_tasks, -1, int)
    status = np.zeros(n_tasks, int)               # 0 wait, 2 ready, 3 run, 4 done
    ready_base = np.zeros(n_tasks)
    ready: List[int] = []                         # FIFO
    pe_free = np.zeros(P)
    now = 0.0
    sched_free = 0.0
    arr_ptr = 0
    n_done = 0
    task_energy = 0.0
    sched_energy = 0.0
    sched_time = 0.0

    def avail_comm(t: int, pe: int) -> float:
        base = ready_base[t]
        for k in range(int(wl.n_preds[t])):
            p = int(wl.preds[t, k])
            comm = (float(wl.out_kb[p]) * cfg.us_per_kb
                    if pe_cluster[pe_of[p]] != pe_cluster[pe] else 0.0)
            base = max(base, finish[p] + comm)
        return base

    def lut_choice():
        t = ready[0]
        cl = int(cfg.lut_cluster[wl.task_type[t]])
        pes = np.where(pe_cluster == cl)[0]
        pe = int(pes[np.argmin(pe_free[pes])])
        return 0, pe

    def etf_choice():
        best = (np.inf, -1, -1)
        for slot, t in enumerate(ready):
            for pe in range(P):
                e = exec_pe[wl.task_type[t], pe]
                if not np.isfinite(e):
                    continue
                ft = max(avail_comm(t, pe), pe_free[pe], now) + e
                if ft < best[0]:
                    best = (ft, slot, pe)
        return best[1], best[2]

    while n_done < n_tasks:
        # 1. completions due
        due = [(finish[t], t) for t in range(n_tasks)
               if status[t] == 3 and finish[t] <= now]
        if due:
            _, t = min(due)
            status[t] = 4
            n_done += 1
            for k in range(int(wl.n_succs[t])):
                s = int(wl.succs[t, k])
                pred_rem[s] -= 1
                if pred_rem[s] == 0:
                    base = max((finish[int(wl.preds[s, j])]
                                for j in range(int(wl.n_preds[s]))),
                               default=now)
                    ready_base[s] = max(base, now)
                    status[s] = 2
                    ready.append(s)
            continue
        # 2. arrivals due
        if arr_ptr < n_inst and wl.inst_arrival[arr_ptr] <= now:
            i = arr_ptr
            arr_ptr += 1
            for k in range(int(wl.inst_n_roots[i])):
                r = int(wl.inst_roots[i, k])
                ready_base[r] = float(wl.inst_arrival[i])
                status[r] = 2
                ready.append(r)
            continue
        # 3. one scheduling decision
        if ready:
            n = float(len(ready))
            if mode == MODE_LUT:
                slot, pe = lut_choice()
                lat, e = float(soc.LUT_LATENCY_US), float(soc.LUT_ENERGY_UJ)
            elif mode == MODE_ETF:
                slot, pe = etf_choice()
                lat = float(soc.etf_latency_us(n))
                e = lat * float(soc.SCHED_POWER_W)
            elif mode == MODE_ETF_IDEAL:
                slot, pe = etf_choice()
                lat, e = 0.0, 0.0
            else:
                raise ValueError(mode)
            t = ready.pop(slot)
            sched_done = max(sched_free, now) + lat
            sched_free = sched_done
            st = max(avail_comm(t, pe), pe_free[pe], sched_done, now)
            ex = float(exec_pe[wl.task_type[t], pe])
            start[t] = st
            finish[t] = st + ex
            pe_of[t] = pe
            pe_free[pe] = finish[t]
            status[t] = 3
            task_energy += ex * float(pe_power[pe])
            sched_energy += e
            sched_time += lat
            continue
        # 4. advance time
        nxt = np.inf
        if arr_ptr < n_inst:
            nxt = min(nxt, float(wl.inst_arrival[arr_ptr]))
        running = finish[status == 3]
        if running.size:
            nxt = min(nxt, float(running.min()))
        if not np.isfinite(nxt):
            break
        now = max(now, nxt)

    inst_fin = np.full(n_inst, -np.inf)
    for t in range(n_tasks):
        inst_fin[int(wl.inst_id[t])] = max(inst_fin[int(wl.inst_id[t])],
                                           finish[t])
    inst_exec = inst_fin - wl.inst_arrival[:n_inst]
    return {
        "avg_exec_us": float(np.mean(inst_exec)),
        "finish": finish,
        "pe_of": pe_of,
        "task_energy_uj": task_energy,
        "sched_energy_uj": sched_energy,
        "sched_time_us": sched_time,
        "n_done": n_done,
    }

"""Independent pure-Python reference simulator (differential oracle).

Implements the same event semantics as the jittable simulator —
completions due, then arrivals due, then one scheduling decision, else
advance — with plain dicts and floats. Used by tests/test_differential.py
to cross-check the lax.while_loop implementation: two independently-written
simulators agreeing on per-task finish times is strong evidence neither
mis-encodes the model.

Tie-breaking contracts replicated exactly:
  * completions: earliest (finish, task-id),
  * LUT: FIFO head task; earliest-free PE within the LUT cluster
    (lowest PE id on ties),
  * ETF: scan ready slots in FIFO order x PEs ascending; strict '<' keeps
    the first minimum (matches argmin over the flattened [R, P] matrix).

Fault mirror (`plan=`): the same event classes and priority order as the
jittable fault path — completion > kill > deadline > arrival > decide >
advance — with identical tie-breaks:
  * kill: earliest fault instant revoking a live assignment
    (`assign_t < tau <= now` on a running task's PE), lowest task id on
    ties; executed work is wasted, the unexecuted tail rolls back its
    energy; within the retry budget the task re-enters the FIFO tail
    re-based at `now`, past it the whole job drops,
  * deadline: earliest arrived-but-incomplete instance past
    `arrival + deadline_us` drops every unfinished task,
  * degraded LUT: most energy-efficient cluster with a live PE,
  * degraded ETF: dead PEs skipped; infeasible decisions fall through to
    advance, whose targets include strictly-future fault/repair instants
    and pending deadlines.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import soc
from repro.core.simulator import (MODE_ETF, MODE_ETF_IDEAL, MODE_LUT)
from repro.core.workloads import FlatWorkload


def simulate_ref(mode: int, wl: FlatWorkload,
                 cfg: soc.SoCConfig | None = None,
                 plan=None) -> Dict:
    cfg = cfg or soc.default_soc()
    exec_pe = cfg.exec_on_pe()                    # [types, P]
    pe_cluster = cfg.pe_cluster
    pe_power = cfg.cluster_power[pe_cluster]
    n_tasks = int(wl.n_tasks)
    n_inst = int(wl.n_insts)
    P = cfg.n_pes

    if plan is not None:
        fail_at = np.asarray(plan.pe_fail_at, float)
        repair_at = np.asarray(plan.pe_repair_at, float)
        kill_times = np.concatenate(
            [fail_at[:, None], np.asarray(plan.transient_at, float)], axis=1)
        pe_slow = np.asarray(plan.cluster_slowdown, float)[pe_cluster]
        max_retries = int(plan.max_retries)
        deadline_us = float(plan.deadline_us)
        fault_times = np.concatenate(
            [fail_at, repair_at, kill_times.reshape(-1)])
    else:
        pe_slow = np.ones(P)

    pred_rem = wl.n_preds.astype(int).copy()
    finish = np.full(n_tasks, np.inf)
    start = np.full(n_tasks, np.inf)
    pe_of = np.full(n_tasks, -1, int)
    status = np.zeros(n_tasks, int)       # 0 wait, 2 ready, 3 run, 4 done,
    #                                       5 dropped with its job
    ready_base = np.zeros(n_tasks)
    ready: List[int] = []                         # FIFO
    pe_free = np.zeros(P)
    pe_alive = np.ones(P, bool)
    now = 0.0
    sched_free = 0.0
    arr_ptr = 0
    n_done = 0
    task_energy = 0.0
    sched_energy = 0.0
    sched_time = 0.0
    # fault accounting
    assign_t = np.full(n_tasks, np.inf)
    retries = np.zeros(n_tasks, int)
    last_kill = np.zeros(n_tasks)
    inst_rem = np.zeros(n_inst, int)
    for t in range(n_tasks):
        inst_rem[int(wl.inst_id[t])] += 1
    job_dropped = np.zeros(n_inst, bool)
    n_kills = n_retries_tot = n_dropped_tasks = n_recovered = 0
    reexec_us = recovery_us = 0.0

    def avail_comm(t: int, pe: int) -> float:
        base = ready_base[t]
        for k in range(int(wl.n_preds[t])):
            p = int(wl.preds[t, k])
            comm = (float(wl.out_kb[p]) * cfg.us_per_kb
                    if pe_cluster[pe_of[p]] != pe_cluster[pe] else 0.0)
            base = max(base, finish[p] + comm)
        return base

    def lut_choice():
        t = ready[0]
        tt = int(wl.task_type[t])
        if plan is None:
            cl = int(cfg.lut_cluster[tt])
        else:
            # energy-ranked fallback over clusters with a live PE
            cl, best_e = -1, np.inf
            for c in range(cfg.n_clusters):
                if not (pe_alive & (pe_cluster == c)).any():
                    continue
                e = float(cfg.task_energy[tt, c])
                if e < best_e:
                    best_e, cl = e, c
            if not np.isfinite(best_e):
                return None
        pes = np.where((pe_cluster == cl) & pe_alive)[0]
        pe = int(pes[np.argmin(pe_free[pes])])
        return 0, pe

    def etf_choice():
        best = (np.inf, -1, -1)
        for slot, t in enumerate(ready):
            for pe in range(P):
                if not pe_alive[pe]:
                    continue
                e = exec_pe[wl.task_type[t], pe] * pe_slow[pe]
                if not np.isfinite(e):
                    continue
                ft = max(avail_comm(t, pe), pe_free[pe], now) + e
                if ft < best[0]:
                    best = (ft, slot, pe)
        if best[1] < 0:
            return None
        return best[1], best[2]

    def rollback_running(victims):
        """Refund the unexecuted tail of running victims and rebuild the
        pe_free of every PE that lost one."""
        nonlocal task_energy
        hit = set()
        for t in victims:
            if status[t] != 3:
                continue
            pe = pe_of[t]
            exec_total = finish[t] - start[t]
            executed = min(max(now - start[t], 0.0), exec_total)
            task_energy -= (exec_total - executed) * float(pe_power[pe])
            hit.add(pe)
        vset = set(victims)
        for pe in hit:
            surv = [finish[u] for u in range(n_tasks)
                    if status[u] == 3 and pe_of[u] == pe and u not in vset]
            pe_free[pe] = max(max(surv, default=-np.inf), now)

    def drop_instance(i: int):
        nonlocal n_done, n_dropped_tasks
        victims = [t for t in range(n_tasks)
                   if int(wl.inst_id[t]) == i and status[t] < 4]
        rollback_running(victims)
        vset = set(victims)
        ready[:] = [t for t in ready if t not in vset]
        for t in victims:
            status[t] = 5
            finish[t] = -np.inf
            start[t] = np.inf
            assign_t[t] = np.inf
        n_done += len(victims)
        n_dropped_tasks += len(victims)
        inst_rem[i] = 0
        job_dropped[i] = True

    while n_done < n_tasks:
        if plan is not None:
            pe_alive = ~((fail_at <= now) & (now < repair_at))
        # 1. completions due
        due = [(finish[t], t) for t in range(n_tasks)
               if status[t] == 3 and finish[t] <= now]
        if due:
            _, t = min(due)
            status[t] = 4
            n_done += 1
            inst_rem[int(wl.inst_id[t])] -= 1
            if plan is not None and retries[t] > 0:
                n_recovered += 1
                recovery_us += finish[t] - last_kill[t]
            for k in range(int(wl.n_succs[t])):
                s = int(wl.succs[t, k])
                pred_rem[s] -= 1
                if pred_rem[s] == 0:
                    base = max((finish[int(wl.preds[s, j])]
                                for j in range(int(wl.n_preds[s]))),
                               default=now)
                    ready_base[s] = max(base, now)
                    status[s] = 2
                    ready.append(s)
            continue
        if plan is not None:
            # 2. fault kills due (earliest tau, lowest task id)
            kt, ktau = -1, np.inf
            for t in range(n_tasks):
                if status[t] != 3:
                    continue
                taus = kill_times[pe_of[t]]
                d = taus[(assign_t[t] < taus) & (taus <= now)]
                if d.size and d.min() < ktau:
                    ktau, kt = float(d.min()), t
            if kt >= 0:
                t = kt
                pe = pe_of[t]
                exec_total = finish[t] - start[t]
                executed = min(max(now - start[t], 0.0), exec_total)
                reexec_us += executed
                rollback_running([t])
                exhausted = retries[t] >= max_retries
                retries[t] += 1
                last_kill[t] = now
                n_kills += 1
                status[t] = 0
                finish[t] = np.inf
                start[t] = np.inf
                pe_of[t] = -1
                assign_t[t] = np.inf
                if exhausted:
                    drop_instance(int(wl.inst_id[t]))
                else:
                    n_retries_tot += 1
                    ready_base[t] = now
                    status[t] = 2
                    ready.append(t)
                continue
            # 3. job deadlines due (earliest deadline, lowest instance id)
            di, ddl = -1, np.inf
            for i in range(min(arr_ptr, n_inst)):
                if inst_rem[i] <= 0:
                    continue
                dl = float(wl.inst_arrival[i]) + deadline_us
                if dl <= now and dl < ddl:
                    ddl, di = dl, i
            if di >= 0:
                drop_instance(di)
                continue
        # 4. arrivals due
        if arr_ptr < n_inst and wl.inst_arrival[arr_ptr] <= now:
            i = arr_ptr
            arr_ptr += 1
            for k in range(int(wl.inst_n_roots[i])):
                r = int(wl.inst_roots[i, k])
                ready_base[r] = float(wl.inst_arrival[i])
                status[r] = 2
                ready.append(r)
            continue
        # 5. one scheduling decision (feasible under the availability mask)
        if ready:
            n = float(len(ready))
            if mode == MODE_LUT:
                choice = lut_choice()
                lat, e = float(soc.LUT_LATENCY_US), float(soc.LUT_ENERGY_UJ)
            elif mode == MODE_ETF:
                choice = etf_choice()
                lat = float(soc.etf_latency_us(n))
                e = lat * float(soc.SCHED_POWER_W)
            elif mode == MODE_ETF_IDEAL:
                choice = etf_choice()
                lat, e = 0.0, 0.0
            else:
                raise ValueError(mode)
            if choice is not None:
                slot, pe = choice
                t = ready.pop(slot)
                sched_done = max(sched_free, now) + lat
                sched_free = sched_done
                st = max(avail_comm(t, pe), pe_free[pe], sched_done, now)
                ex = float(exec_pe[wl.task_type[t], pe]) * float(pe_slow[pe])
                start[t] = st
                finish[t] = st + ex
                pe_of[t] = pe
                pe_free[pe] = finish[t]
                status[t] = 3
                assign_t[t] = now
                task_energy += ex * float(pe_power[pe])
                sched_energy += e
                sched_time += lat
                continue
        # 6. advance time
        nxt = np.inf
        if arr_ptr < n_inst:
            nxt = min(nxt, float(wl.inst_arrival[arr_ptr]))
        running = finish[status == 3]
        if running.size:
            nxt = min(nxt, float(running.min()))
        if plan is not None:
            fut = fault_times[fault_times > now]
            if fut.size:
                nxt = min(nxt, float(fut.min()))
            for i in range(min(arr_ptr, n_inst)):
                if inst_rem[i] > 0:
                    dl = float(wl.inst_arrival[i]) + deadline_us
                    if dl > now:
                        nxt = min(nxt, dl)
        if not np.isfinite(nxt):
            break
        now = max(now, nxt)

    inst_fin = np.full(n_inst, -np.inf)
    for t in range(n_tasks):
        inst_fin[int(wl.inst_id[t])] = max(inst_fin[int(wl.inst_id[t])],
                                           finish[t])
    inst_exec = inst_fin - wl.inst_arrival[:n_inst]
    kept = ~job_dropped
    return {
        "avg_exec_us": float(np.mean(inst_exec[kept])) if kept.any()
        else float("nan"),
        "finish": finish,
        "pe_of": pe_of,
        "task_energy_uj": task_energy,
        "sched_energy_uj": sched_energy,
        "sched_time_us": sched_time,
        "n_done": n_done,
        "n_faults": n_kills,
        "n_retries": n_retries_tot,
        "reexec_us": reexec_us,
        "n_dropped_jobs": int(job_dropped.sum()),
        "n_dropped_tasks": n_dropped_tasks,
        "recovery_us": recovery_us,
        "n_recovered": n_recovered,
        "job_dropped": job_dropped,
    }

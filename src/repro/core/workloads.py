"""Workload construction: 40 application mixes x 14 data rates.

A workload is a stream of application *instances* (frames) arriving at a rate
set by the input data rate (Mbps). Frames are pipelined: a new frame enters
the SoC every `FRAME_KBITS / rate` microseconds (plus deterministic jitter).

The flattened representation (`FlatWorkload`) stores every task of every
instance in one set of fixed-size arrays so the whole simulation jits.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Sequence

import numpy as np

from repro.core import dfg

# The paper sweeps 14 data rates; these span lightly-loaded to congested.
DATA_RATES_MBPS = np.array(
    [62.5, 125, 187.5, 250, 375, 500, 625, 750, 875, 1000, 1250, 1500, 1750,
     2000],
    dtype=np.float32,
)
N_DATA_RATES = len(DATA_RATES_MBPS)
FRAME_KBITS = np.float32(1.0)  # one frame = 1 kbit of input data


def interarrival_us(rate_mbps: float) -> float:
    """Mean inter-frame arrival gap for a given input data rate."""
    return float(FRAME_KBITS * 1e3 / rate_mbps)  # kbit / (Mbit/s) = ms*? ->
    # 16e3 bits / (rate 1e6 bit/s) = 16e-3/rate s = 16000/rate us.


# ---------------------------------------------------------------------------
# The 40 workload mixes (fractions over the five apps). Follows the paper:
# "ranging from all instances belonging to a single application to a uniform
# distribution from all five applications".
# ---------------------------------------------------------------------------
def workload_mixes() -> np.ndarray:
    """[40, 5] application mix ratios (rows sum to 1)."""
    rng = np.random.RandomState(7)
    mixes: List[np.ndarray] = []
    eye = np.eye(dfg.N_APPS, dtype=np.float64)
    for i in range(dfg.N_APPS):            # 5 single-app workloads
        mixes.append(eye[i])
    mixes.append(np.full(dfg.N_APPS, 1.0 / dfg.N_APPS))  # uniform
    for i in range(dfg.N_APPS):            # 5 pairwise 50/50 mixes
        mixes.append((eye[i] + eye[(i + 1) % dfg.N_APPS]) / 2.0)
    for i in range(dfg.N_APPS):            # 5 dominated mixes (60/10/10/10/10)
        m = np.full(dfg.N_APPS, 0.1)
        m[i] = 0.6
        mixes.append(m)
    while len(mixes) < 40:                 # random Dirichlet mixes
        m = rng.dirichlet(np.ones(dfg.N_APPS))
        mixes.append(m)
    return np.stack(mixes[:40]).astype(np.float32)


class FlatWorkload(NamedTuple):
    """Fixed-size flattened task arrays for one workload (numpy, host side).

    All arrays are padded to t_max tasks / i_max instances; `task_valid`
    and `inst_valid` mask the padding.
    """

    # per-task
    task_type: np.ndarray     # [T] int32
    inst_id: np.ndarray       # [T] int32  (instance index)
    app_id: np.ndarray        # [T] int32
    depth: np.ndarray         # [T] int32
    out_kb: np.ndarray        # [T] float32
    preds: np.ndarray         # [T, MAX_PREDS] int32, -1 pad
    n_preds: np.ndarray       # [T] int32
    succs: np.ndarray         # [T, MAX_SUCCS] int32, -1 pad
    n_succs: np.ndarray       # [T] int32
    task_valid: np.ndarray    # [T] bool
    # per-instance
    inst_arrival: np.ndarray  # [I] float32 (us)
    inst_app: np.ndarray      # [I] int32
    inst_task_start: np.ndarray  # [I] int32 (tasks of an instance contiguous)
    inst_task_count: np.ndarray  # [I] int32
    inst_roots: np.ndarray    # [I, MAX_ROOTS] int32, -1 pad
    inst_n_roots: np.ndarray  # [I] int32
    inst_valid: np.ndarray    # [I] bool
    # scalars
    n_tasks: np.ndarray       # [] int32 (valid count)
    n_insts: np.ndarray       # [] int32
    rate_mbps: np.ndarray     # [] float32


def build_workload(
    mix: Sequence[float],
    rate_mbps: float,
    n_instances: int,
    seed: int,
    t_max: int | None = None,
    i_max: int | None = None,
) -> FlatWorkload:
    """Instantiate a workload: deterministic app interleave + Poisson-ish
    arrivals around the frame-pipelined mean gap."""
    mix = np.asarray(mix, dtype=np.float64)
    mix = mix / mix.sum()
    rng = np.random.RandomState(seed)

    # Deterministic proportional interleave of app instances (largest
    # remainder per step) so every prefix matches the mix.
    counts = np.zeros(dfg.N_APPS)
    inst_apps = np.empty(n_instances, dtype=np.int32)
    for i in range(n_instances):
        deficit = mix * (i + 1) - counts
        a = int(np.argmax(deficit))
        inst_apps[i] = a
        counts[a] += 1

    gap = interarrival_us(rate_mbps)
    # exponential inter-arrivals with the pipelined mean (streaming frames)
    gaps = rng.exponential(gap, size=n_instances).astype(np.float64)
    gaps[0] = 0.0
    arrivals = np.cumsum(gaps).astype(np.float32)

    if i_max is None:
        i_max = n_instances
    if t_max is None:
        t_max = int(sum(dfg.APPS[dfg.APP_NAMES[a]].n_tasks for a in inst_apps))
    assert i_max >= n_instances

    MP, MS, MR = dfg.MAX_PREDS, dfg.MAX_SUCCS, dfg.MAX_ROOTS
    task_type = np.zeros(t_max, np.int32)
    inst_id = np.zeros(t_max, np.int32)
    app_id = np.zeros(t_max, np.int32)
    depth = np.zeros(t_max, np.int32)
    out_kb = np.zeros(t_max, np.float32)
    preds = np.full((t_max, MP), -1, np.int32)
    n_preds = np.zeros(t_max, np.int32)
    succs = np.full((t_max, MS), -1, np.int32)
    n_succs = np.zeros(t_max, np.int32)
    task_valid = np.zeros(t_max, np.bool_)

    inst_arrival = np.full(i_max, np.inf, np.float32)
    inst_app = np.zeros(i_max, np.int32)
    inst_task_start = np.zeros(i_max, np.int32)
    inst_task_count = np.zeros(i_max, np.int32)
    inst_roots = np.full((i_max, MR), -1, np.int32)
    inst_n_roots = np.zeros(i_max, np.int32)
    inst_valid = np.zeros(i_max, np.bool_)

    cursor = 0
    for i in range(n_instances):
        a = int(inst_apps[i])
        g = dfg.APPS[dfg.APP_NAMES[a]]
        n = g.n_tasks
        assert cursor + n <= t_max, "t_max too small for workload"
        sl = slice(cursor, cursor + n)
        task_type[sl] = g.task_types
        inst_id[sl] = i
        app_id[sl] = a
        depth[sl] = g.depths()
        out_kb[sl] = g.out_kb
        gsuccs = g.succs()
        roots = []
        for j in range(n):
            p = g.preds[j]
            n_preds[cursor + j] = len(p)
            for k, q in enumerate(p):
                preds[cursor + j, k] = cursor + q
            s = gsuccs[j]
            n_succs[cursor + j] = len(s)
            for k, q in enumerate(s):
                succs[cursor + j, k] = cursor + q
            if not p:
                roots.append(cursor + j)
        task_valid[sl] = True
        inst_arrival[i] = arrivals[i]
        inst_app[i] = a
        inst_task_start[i] = cursor
        inst_task_count[i] = n
        inst_n_roots[i] = len(roots)
        for k, r in enumerate(roots):
            inst_roots[i, k] = r
        inst_valid[i] = True
        cursor += n

    return validate_workload(FlatWorkload(
        task_type=task_type, inst_id=inst_id, app_id=app_id, depth=depth,
        out_kb=out_kb, preds=preds, n_preds=n_preds, succs=succs,
        n_succs=n_succs, task_valid=task_valid, inst_arrival=inst_arrival,
        inst_app=inst_app, inst_task_start=inst_task_start,
        inst_task_count=inst_task_count, inst_roots=inst_roots,
        inst_n_roots=inst_n_roots, inst_valid=inst_valid,
        n_tasks=np.int32(cursor), n_insts=np.int32(n_instances),
        rate_mbps=np.float32(rate_mbps),
    ))


def validate_workload(wl: FlatWorkload) -> FlatWorkload:
    """Build-time sanity checks; a malformed workload inside the jitted
    simulator produces NaN results or a silent stall, not an error, so
    fail loudly here instead."""
    from repro.core import soc

    T = int(wl.n_tasks)
    I = int(wl.n_insts)
    Tp = wl.task_type.shape[0]
    if T < 0 or T > Tp or not wl.task_valid[:T].all() \
            or wl.task_valid[T:].any():
        raise ValueError(
            f"FlatWorkload: task_valid must be a prefix of length "
            f"n_tasks={T} (padded to {Tp})")
    if I < 0 or I > wl.inst_valid.shape[0] or not wl.inst_valid[:I].all() \
            or wl.inst_valid[I:].any():
        raise ValueError(
            f"FlatWorkload: inst_valid must be a prefix of length "
            f"n_insts={I}")
    tt = wl.task_type[:T]
    if ((tt < 0) | (tt >= soc.N_TASK_TYPES)).any():
        bad = np.where((tt < 0) | (tt >= soc.N_TASK_TYPES))[0][:5]
        raise ValueError(
            f"FlatWorkload: task_type out of range [0, {soc.N_TASK_TYPES}) "
            f"at tasks {bad.tolist()}")
    kb = wl.out_kb[:T]
    if np.isnan(kb).any() or (kb < 0).any() or np.isinf(kb).any():
        raise ValueError("FlatWorkload: out_kb must be finite and >= 0")
    arr = wl.inst_arrival[:I]
    if np.isnan(arr).any() or (arr < 0).any() or np.isinf(arr).any():
        raise ValueError(
            "FlatWorkload: inst_arrival must be finite and >= 0")
    if ((wl.inst_id[:T] < 0) | (wl.inst_id[:T] >= max(I, 1))).any():
        raise ValueError("FlatWorkload: inst_id out of range")
    for name, idx, cnt in (("preds", wl.preds, wl.n_preds),
                           ("succs", wl.succs, wl.n_succs)):
        k = np.arange(idx.shape[1])[None, :]
        valid = k < cnt[:T, None]
        v = idx[:T]
        if ((cnt[:T] < 0) | (cnt[:T] > idx.shape[1])).any():
            raise ValueError(f"FlatWorkload: n_{name} out of range")
        if (valid & ((v < 0) | (v >= T))).any():
            raise ValueError(f"FlatWorkload: {name} index out of range")
    # acyclicity: the flattened ids are a topological order by
    # construction, so every predecessor must precede its consumer — a
    # cycle cannot satisfy that for all of its edges
    k = np.arange(wl.preds.shape[1])[None, :]
    pvalid = k < wl.n_preds[:T, None]
    tasks = np.arange(T)[:, None]
    if (pvalid & (wl.preds[:T] >= tasks)).any():
        bad = np.where((pvalid & (wl.preds[:T] >= tasks)).any(axis=1))[0][:5]
        raise ValueError(
            f"FlatWorkload: dependency cycle or forward pred edge at tasks "
            f"{bad.tolist()} (predecessor id >= task id)")
    if not (np.isfinite(wl.rate_mbps) and wl.rate_mbps > 0):
        raise ValueError("FlatWorkload: rate_mbps must be finite and > 0")
    return wl


def stack_workloads(wls: Sequence[FlatWorkload]) -> FlatWorkload:
    """Stack same-shape workloads into a leading scenario axis.

    Every field of the result carries a leading `[S]` axis (scalars such as
    `n_tasks` become `[S]` vectors). Workloads built from one
    `WorkloadSuite` share padded shapes by construction, so a (mix x rate)
    sweep stacks directly; the result feeds `simulator.simulate_batch` /
    `run_batch`, which `jax.vmap` the jitted simulator over the axis.
    """
    if not wls:
        raise ValueError("stack_workloads: need at least one workload")
    for wl in wls[1:]:
        for a, b, name in zip(wl, wls[0], FlatWorkload._fields):
            if np.shape(a) != np.shape(b):
                raise ValueError(
                    f"stack_workloads: field {name!r} shape mismatch "
                    f"{np.shape(a)} vs {np.shape(b)}; build all scenarios "
                    "from one suite (shared t_max/i_max)")
    return FlatWorkload(*[
        np.stack([np.asarray(f) for f in fields])
        for fields in zip(*wls)
    ])


@dataclasses.dataclass(frozen=True)
class WorkloadSuite:
    """The benchmark suite: mixes x rates, shared padded shapes."""

    mixes: np.ndarray
    rates: np.ndarray
    n_instances: int
    t_max: int
    i_max: int

    def build(self, mix_idx: int, rate_idx: int, seed: int = 0) -> FlatWorkload:
        return build_workload(
            self.mixes[mix_idx], float(self.rates[rate_idx]),
            self.n_instances, seed=seed + 1000 * mix_idx + rate_idx,
            t_max=self.t_max, i_max=self.i_max,
        )

    def build_many(self, cells: Sequence[tuple], seed: int = 0) -> FlatWorkload:
        """Build and stack the scenarios `[(mix_idx, rate_idx), ...]`."""
        return stack_workloads(
            [self.build(mi, ri, seed=seed) for mi, ri in cells]
        )


def default_suite(n_instances: int = 40) -> WorkloadSuite:
    mixes = workload_mixes()
    t_max = n_instances * dfg.MAX_APP_TASKS  # upper bound, shared shape
    return WorkloadSuite(
        mixes=mixes, rates=DATA_RATES_MBPS, n_instances=n_instances,
        t_max=t_max, i_max=n_instances,
    )

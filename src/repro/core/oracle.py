"""Two-execution oracle generation for DAS training data (paper Fig. 1).

First execution (MODE_ORACLE): both schedulers run at every decision; if they
agree the sample is labeled F immediately; otherwise the label is *pending*
and the fast decision is followed.

Second execution (MODE_ETF): the same scenario follows the slow scheduler
throughout. If the target metric (avg execution time or EDP) improves versus
the first execution, pending labels become S, else F.

`generate` runs the whole (mix x rate) grid through two batched simulator
calls (`sim.run_batch`, one `MODE_ORACLE` + one `MODE_ETF` sweep, vmapped
over the scenario axis) instead of 2 x len(grid) sequential runs; the
resulting dataset is bit-identical to the sequential path
(`batched=False`), which is kept for differential testing.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Tuple

import numpy as np

from repro.core import simulator as sim
from repro.core.workloads import FlatWorkload, WorkloadSuite

LABEL_F, LABEL_S = 0, 1


@dataclasses.dataclass
class OracleDataset:
    features: np.ndarray   # [N, N_FEATURES] f32
    labels: np.ndarray     # [N] int32 (0=F, 1=S)
    groups: np.ndarray     # [N] int32 (workload-mix id of each sample)
    rates: np.ndarray      # [N] f32 (nominal data rate of the run)

    def __len__(self) -> int:
        return int(self.labels.shape[0])


def label_one_run(
    wl: FlatWorkload,
    params: sim.SimParams,
    metric: str = "avg_exec_us",
) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Run the two executions for one (workload, rate) scenario.

    Returns (features [D, F], labels [D], info).
    """
    r1 = sim.run(sim.MODE_ORACLE, wl, params)   # follows fast
    r2 = sim.run(sim.MODE_ETF, wl, params)      # follows slow
    n_dec = int(r1.n_decisions)
    feats = np.asarray(r1.log_feat)[:n_dec]
    agree = np.asarray(r1.log_agree)[:n_dec].astype(bool)

    m1 = float(getattr(r1, metric))
    m2 = float(getattr(r2, metric))
    pending_label = LABEL_S if m2 < m1 else LABEL_F
    labels = np.where(agree, LABEL_F, pending_label).astype(np.int32)
    info = {
        "metric_fast_run": m1,
        "metric_slow_run": m2,
        "pending_label": pending_label,
        "n_decisions": n_dec,
        "agreement_rate": float(agree.mean()) if n_dec else 0.0,
    }
    return feats, labels, info


def generate(
    suite: WorkloadSuite,
    params: sim.SimParams | None = None,
    mix_indices: Iterable[int] | None = None,
    rate_indices: Iterable[int] | None = None,
    metric: str = "avg_exec_us",
    seed: int = 0,
    verbose: bool = False,
    batched: bool = True,
    batch_size: int | None = None,
    runner=None,
) -> OracleDataset:
    """Generate the oracle dataset over (mix x rate) scenarios.

    With `batched=True` (default) all scenarios are built up front and
    labeled from one vmapped `MODE_ORACLE` sweep plus one vmapped
    `MODE_ETF` sweep; `batch_size` chunks the scenario axis to bound
    memory (see `sim.run_batch`). `batched=False` is the original
    scenario-at-a-time loop; both paths produce identical datasets.

    `runner` swaps the sweep engine for the batched path: a callable
    `(mode, stacked, params, batch_size) -> SimResult` — the benchmarks
    pass the crash-safe campaign runner (`benchmarks.common.sweep`) so
    oracle generation checkpoints and resumes like every other grid.
    """
    params = params or sim.make_params()
    mix_indices = list(mix_indices if mix_indices is not None
                       else range(suite.mixes.shape[0]))
    rate_indices = list(rate_indices if rate_indices is not None
                        else range(len(suite.rates)))
    cells = [(mi, ri) for mi in mix_indices for ri in rate_indices]

    feats: List[np.ndarray] = []
    labels: List[np.ndarray] = []
    groups: List[np.ndarray] = []
    rates: List[np.ndarray] = []

    def emit(mi, ri, f, l, info):
        feats.append(f)
        labels.append(l)
        groups.append(np.full(l.shape[0], mi, np.int32))
        rates.append(np.full(l.shape[0], float(suite.rates[ri]),
                             np.float32))
        if verbose:
            print(f"mix={mi:2d} rate={float(suite.rates[ri]):7.1f} "
                  f"n={info['n_decisions']:5d} "
                  f"agree={info['agreement_rate']:.2f} "
                  f"pending->{'S' if info['pending_label'] else 'F'} "
                  f"(F-run {info['metric_fast_run']:.2f} vs "
                  f"S-run {info['metric_slow_run']:.2f})")

    if batched:
        if runner is None:
            def runner(m, s, p, bs):
                return sim.run_batch(m, s, p, batch_size=bs)
        stacked = suite.build_many(cells, seed=seed)
        r1 = runner(sim.MODE_ORACLE, stacked, params, batch_size)
        r2 = runner(sim.MODE_ETF, stacked, params, batch_size)
        all_n_dec = np.asarray(r1.n_decisions)
        all_feat = np.asarray(r1.log_feat)
        all_agree = np.asarray(r1.log_agree)
        all_m1 = np.asarray(getattr(r1, metric))
        all_m2 = np.asarray(getattr(r2, metric))
        for k, (mi, ri) in enumerate(cells):
            n_dec = int(all_n_dec[k])
            f = all_feat[k, :n_dec]
            agree = all_agree[k, :n_dec].astype(bool)
            m1, m2 = float(all_m1[k]), float(all_m2[k])
            pending_label = LABEL_S if m2 < m1 else LABEL_F
            l = np.where(agree, LABEL_F, pending_label).astype(np.int32)
            emit(mi, ri, f, l, {
                "metric_fast_run": m1, "metric_slow_run": m2,
                "pending_label": pending_label, "n_decisions": n_dec,
                "agreement_rate": float(agree.mean()) if n_dec else 0.0,
            })
    else:
        for mi, ri in cells:
            wl = suite.build(mi, ri, seed=seed)
            f, l, info = label_one_run(wl, params, metric=metric)
            emit(mi, ri, f, l, info)

    return OracleDataset(
        features=np.concatenate(feats, axis=0),
        labels=np.concatenate(labels, axis=0),
        groups=np.concatenate(groups, axis=0),
        rates=np.concatenate(rates, axis=0),
    )


def train_test_split(ds: OracleDataset, test_frac: float = 0.25,
                     seed: int = 0):
    rng = np.random.RandomState(seed)
    n = len(ds)
    idx = rng.permutation(n)
    cut = int(n * (1 - test_frac))
    tr, te = idx[:cut], idx[cut:]
    mk = lambda ii: OracleDataset(ds.features[ii], ds.labels[ii],
                                  ds.groups[ii], ds.rates[ii])
    return mk(tr), mk(te)

"""Two-execution oracle generation for DAS training data (paper Fig. 1).

First execution (MODE_ORACLE): both schedulers run at every decision; if they
agree the sample is labeled F immediately; otherwise the label is *pending*
and the fast decision is followed.

Second execution (MODE_ETF): the same scenario follows the slow scheduler
throughout. If the target metric (avg execution time or EDP) improves versus
the first execution, pending labels become S, else F.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Tuple

import numpy as np

from repro.core import simulator as sim
from repro.core.workloads import FlatWorkload, WorkloadSuite

LABEL_F, LABEL_S = 0, 1


@dataclasses.dataclass
class OracleDataset:
    features: np.ndarray   # [N, N_FEATURES] f32
    labels: np.ndarray     # [N] int32 (0=F, 1=S)
    groups: np.ndarray     # [N] int32 (workload-mix id of each sample)
    rates: np.ndarray      # [N] f32 (nominal data rate of the run)

    def __len__(self) -> int:
        return int(self.labels.shape[0])


def label_one_run(
    wl: FlatWorkload,
    params: sim.SimParams,
    metric: str = "avg_exec_us",
) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Run the two executions for one (workload, rate) scenario.

    Returns (features [D, F], labels [D], info).
    """
    r1 = sim.run(sim.MODE_ORACLE, wl, params)   # follows fast
    r2 = sim.run(sim.MODE_ETF, wl, params)      # follows slow
    n_dec = int(r1.n_decisions)
    feats = np.asarray(r1.log_feat)[:n_dec]
    agree = np.asarray(r1.log_agree)[:n_dec].astype(bool)

    m1 = float(getattr(r1, metric))
    m2 = float(getattr(r2, metric))
    pending_label = LABEL_S if m2 < m1 else LABEL_F
    labels = np.where(agree, LABEL_F, pending_label).astype(np.int32)
    info = {
        "metric_fast_run": m1,
        "metric_slow_run": m2,
        "pending_label": pending_label,
        "n_decisions": n_dec,
        "agreement_rate": float(agree.mean()) if n_dec else 0.0,
    }
    return feats, labels, info


def generate(
    suite: WorkloadSuite,
    params: sim.SimParams | None = None,
    mix_indices: Iterable[int] | None = None,
    rate_indices: Iterable[int] | None = None,
    metric: str = "avg_exec_us",
    seed: int = 0,
    verbose: bool = False,
) -> OracleDataset:
    """Generate the oracle dataset over (mix x rate) scenarios."""
    params = params or sim.make_params()
    mix_indices = list(mix_indices if mix_indices is not None
                       else range(suite.mixes.shape[0]))
    rate_indices = list(rate_indices if rate_indices is not None
                        else range(len(suite.rates)))
    feats: List[np.ndarray] = []
    labels: List[np.ndarray] = []
    groups: List[np.ndarray] = []
    rates: List[np.ndarray] = []
    for mi in mix_indices:
        for ri in rate_indices:
            wl = suite.build(mi, ri, seed=seed)
            f, l, info = label_one_run(wl, params, metric=metric)
            feats.append(f)
            labels.append(l)
            groups.append(np.full(l.shape[0], mi, np.int32))
            rates.append(np.full(l.shape[0], float(suite.rates[ri]),
                                 np.float32))
            if verbose:
                print(f"mix={mi:2d} rate={float(suite.rates[ri]):7.1f} "
                      f"n={info['n_decisions']:5d} "
                      f"agree={info['agreement_rate']:.2f} "
                      f"pending->{'S' if info['pending_label'] else 'F'} "
                      f"(F-run {info['metric_fast_run']:.2f} vs "
                      f"S-run {info['metric_slow_run']:.2f})")
    return OracleDataset(
        features=np.concatenate(feats, axis=0),
        labels=np.concatenate(labels, axis=0),
        groups=np.concatenate(groups, axis=0),
        rates=np.concatenate(rates, axis=0),
    )


def train_test_split(ds: OracleDataset, test_frac: float = 0.25,
                     seed: int = 0):
    rng = np.random.RandomState(seed)
    n = len(ds)
    idx = rng.permutation(n)
    cut = int(n * (1 - test_frac))
    tr, te = idx[:cut], idx[cut:]
    mk = lambda ii: OracleDataset(ds.features[ii], ds.labels[ii],
                                  ds.groups[ii], ds.rates[ii])
    return mk(tr), mk(te)

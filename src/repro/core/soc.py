"""DSSoC hardware model for the DAS reproduction.

The paper's DSSoC (Section IV-A): Arm big.LITTLE (4+4 cores) plus dedicated
accelerators — 4x FFT, 4x FIR, 1x FEC, 2x SAP (systolic array processor) —
19 PEs total, mesh NoC.

Exact DS3 task profiles are not published in the paper; the tables below are
synthesized to match the paper's premises (accelerated tasks run 1-2 orders of
magnitude faster on their accelerator than on general-purpose cores; LITTLE is
the energy-efficient CPU; big is the fast CPU). All times are microseconds,
power in watts, energy in microjoules. See DESIGN.md, "Hardware model
calibration".
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

# ----------------------------------------------------------------------------
# Clusters and PEs
# ----------------------------------------------------------------------------
CLUSTER_NAMES = ("big", "little", "fft", "fir", "fec", "sap")
N_CLUSTERS = len(CLUSTER_NAMES)
BIG, LITTLE, FFT_ACC, FIR_ACC, FEC_ACC, SAP_ACC = range(N_CLUSTERS)

# PEs per cluster: 4 big + 4 LITTLE + 4 FFT + 4 FIR + 1 FEC + 2 SAP = 19.
PES_PER_CLUSTER = (4, 4, 4, 4, 1, 2)
N_PES = sum(PES_PER_CLUSTER)  # 19

# pe -> cluster map
PE_CLUSTER = np.concatenate(
    [np.full(n, c, dtype=np.int32) for c, n in enumerate(PES_PER_CLUSTER)]
)
# first PE index of each cluster
CLUSTER_PE_START = np.cumsum((0,) + PES_PER_CLUSTER[:-1]).astype(np.int32)
# (cluster, pe) membership mask, shape [N_CLUSTERS, N_PES]
CLUSTER_PE_MASK = np.stack(
    [PE_CLUSTER == c for c in range(N_CLUSTERS)]
).astype(np.bool_)

# ----------------------------------------------------------------------------
# Task types (the domain kernel vocabulary: wireless comms + radar)
# ----------------------------------------------------------------------------
TASK_TYPE_NAMES = (
    "scrambler",     # 0  CPU-only
    "interleaver",   # 1  CPU-only
    "qpsk_mod",      # 2  CPU-only
    "pilot_insert",  # 3  CPU-only
    "fft",           # 4  FFT accelerator
    "ifft",          # 5  FFT accelerator
    "fir",           # 6  FIR accelerator
    "fec_enc",       # 7  FEC accelerator
    "fec_dec",       # 8  FEC accelerator (viterbi)
    "matmul",        # 9  systolic array (SAP)
    "demod",         # 10 CPU-only
    "sync",          # 11 CPU-only
)
N_TASK_TYPES = len(TASK_TYPE_NAMES)

_INF = np.float32(np.inf)

# exec time (us) per [task_type, cluster]; inf = cluster cannot run the type.
# CPUs (big, LITTLE) can run everything. Calibration (see DESIGN.md):
# accelerated kernels are sub-microsecond on their accelerator (the paper's
# "order of nanoseconds" premise), 30-80x slower on CPUs; the small
# control-plane tasks are near-parity between big and LITTLE (so the
# energy-efficient LITTLE placement is also close to time-optimal at low
# load, as in the paper where LUT ~= ETF-ideal at low rates), while heavy
# kernels are ~1.6x slower on LITTLE.
# Control-plane kernels (sub-us, memory/IO-bound) run at time-parity on big
# and LITTLE (LITTLE wins on energy only); compute-bound kernels are ~1.6x
# slower on LITTLE. This mirrors the paper's low-rate regime where the
# energy-optimal (LUT) placement is also time-near-optimal.
EXEC_TIME = np.array(
    #  big    little  fft    fir    fec    sap
    [[ 0.45,   0.45, _INF,  _INF,  _INF,  _INF],   # scrambler
     [ 0.55,   0.55, _INF,  _INF,  _INF,  _INF],   # interleaver
     [ 0.70,   0.70, _INF,  _INF,  _INF,  _INF],   # qpsk_mod
     [ 0.35,   0.35, _INF,  _INF,  _INF,  _INF],   # pilot_insert
     [ 2.00,   3.20,  0.10, _INF,  _INF,  _INF],   # fft
     [ 2.00,   3.20,  0.10, _INF,  _INF,  _INF],   # ifft
     [ 1.40,   2.20, _INF,   0.07, _INF,  _INF],   # fir
     [ 2.80,   4.40, _INF,  _INF,   0.35, _INF],   # fec_enc
     [ 4.40,   7.00, _INF,  _INF,   0.55, _INF],   # fec_dec (viterbi)
     [ 3.00,   4.80, _INF,  _INF,  _INF,   0.30],  # matmul (systolic)
     [ 0.75,   0.75, _INF,  _INF,  _INF,  _INF],   # demod
     [ 0.90,   0.90, _INF,  _INF,  _INF,  _INF]],  # sync
    dtype=np.float32,
)

# active power (W) per cluster while executing a task
CLUSTER_POWER = np.array([1.8, 0.45, 0.45, 0.40, 0.50, 0.90], dtype=np.float32)

# energy (uJ) per [task_type, cluster] = exec_time * power
TASK_ENERGY = np.where(
    np.isfinite(EXEC_TIME), EXEC_TIME * CLUSTER_POWER[None, :], _INF
).astype(np.float32)

# ----------------------------------------------------------------------------
# LUT (fast scheduler) table: most energy-efficient cluster per task type.
# The paper: "The LUT stores the most energy-efficient processor in the target
# system for each known task"; unknown tasks -> next available CPU core.
# ----------------------------------------------------------------------------
LUT_CLUSTER = np.argmin(TASK_ENERGY, axis=1).astype(np.int32)

# ----------------------------------------------------------------------------
# NoC communication model: crossing clusters costs data_kb * US_PER_KB.
# Same-cluster communication is free (shared scratchpad / L2).
# ----------------------------------------------------------------------------
US_PER_KB = np.float32(0.02)  # ~50 GB/s effective NoC bandwidth

# ----------------------------------------------------------------------------
# Scheduler overhead models (Section III-C / IV-C of the paper)
# ----------------------------------------------------------------------------
# Fast (LUT) scheduler: ~7.2 cycles = 6 ns on A53 @1.2GHz, 2.3 nJ.
LUT_LATENCY_US = np.float32(0.006)
LUT_ENERGY_UJ = np.float32(0.0023)
# DAS preselection classifier: 13 ns in the background (zero critical-path
# latency), ~1.9 nJ per refresh -> DAS fast-path total 4.2 nJ (paper).
DAS_CLS_ENERGY_UJ = np.float32(0.0019)
# Slow (ETF) scheduler: quadratic in the ready-queue length n (the paper fits
# a quadratic to ZCU102 measurements; constants chosen so that light queues
# cost tens of ns and DAS's heavy-load average lands near 65 ns / 27.2 nJ).
ETF_LAT_C0 = np.float32(0.040)    # us
ETF_LAT_C1 = np.float32(0.0035)   # us per ready task
ETF_LAT_C2 = np.float32(0.0003)   # us per ready task^2
SCHED_POWER_W = np.float32(0.42)  # A53 core power while scheduling


def etf_latency_us(n_ready) -> np.ndarray:
    """Quadratic ETF decision latency model (vectorizes; jnp-compatible)."""
    n = n_ready
    return ETF_LAT_C0 + ETF_LAT_C1 * n + ETF_LAT_C2 * n * n


@dataclasses.dataclass(frozen=True)
class SoCConfig:
    """Bundles the hardware model as plain arrays (host side, numpy)."""

    n_pes: int = N_PES
    n_clusters: int = N_CLUSTERS
    n_task_types: int = N_TASK_TYPES
    pe_cluster: np.ndarray = dataclasses.field(default_factory=lambda: PE_CLUSTER)
    cluster_pe_mask: np.ndarray = dataclasses.field(
        default_factory=lambda: CLUSTER_PE_MASK
    )
    exec_time: np.ndarray = dataclasses.field(default_factory=lambda: EXEC_TIME)
    cluster_power: np.ndarray = dataclasses.field(
        default_factory=lambda: CLUSTER_POWER
    )
    task_energy: np.ndarray = dataclasses.field(default_factory=lambda: TASK_ENERGY)
    lut_cluster: np.ndarray = dataclasses.field(default_factory=lambda: LUT_CLUSTER)
    us_per_kb: float = float(US_PER_KB)

    def exec_on_pe(self) -> np.ndarray:
        """[task_type, pe] execution-time table."""
        return self.exec_time[:, self.pe_cluster]


def validate_config(cfg: SoCConfig) -> SoCConfig:
    """Sanity-check a hardware model before it reaches the jitted simulator
    (a malformed table there turns into NaN results, not errors)."""
    pe_cluster = np.asarray(cfg.pe_cluster)
    mask = np.asarray(cfg.cluster_pe_mask)
    exec_t = np.asarray(cfg.exec_time)
    power = np.asarray(cfg.cluster_power)
    energy = np.asarray(cfg.task_energy)
    lut = np.asarray(cfg.lut_cluster)
    if pe_cluster.shape != (cfg.n_pes,):
        raise ValueError(
            f"SoCConfig: pe_cluster shape {pe_cluster.shape} != ({cfg.n_pes},)")
    if ((pe_cluster < 0) | (pe_cluster >= cfg.n_clusters)).any():
        raise ValueError("SoCConfig: pe_cluster entries out of range")
    if mask.shape != (cfg.n_clusters, cfg.n_pes):
        raise ValueError(
            f"SoCConfig: cluster_pe_mask shape {mask.shape} != "
            f"({cfg.n_clusters}, {cfg.n_pes})")
    if not (mask.sum(axis=0) == 1).all():
        raise ValueError("SoCConfig: every PE must belong to exactly one "
                         "cluster in cluster_pe_mask")
    for name, table in (("exec_time", exec_t), ("task_energy", energy)):
        if table.shape != (cfg.n_task_types, cfg.n_clusters):
            raise ValueError(
                f"SoCConfig: {name} shape {table.shape} != "
                f"({cfg.n_task_types}, {cfg.n_clusters})")
        if np.isnan(table).any():
            raise ValueError(f"SoCConfig: {name} contains NaN")
        if (table[np.isfinite(table)] <= 0).any():
            raise ValueError(f"SoCConfig: {name} entries must be positive "
                             "(inf = cannot run)")
    if not np.isfinite(exec_t).any(axis=1).all():
        raise ValueError("SoCConfig: some task type cannot run anywhere")
    if power.shape != (cfg.n_clusters,) or (power <= 0).any() \
            or np.isnan(power).any():
        raise ValueError("SoCConfig: cluster_power must be positive, "
                         f"shape ({cfg.n_clusters},)")
    if lut.shape != (cfg.n_task_types,) \
            or ((lut < 0) | (lut >= cfg.n_clusters)).any():
        raise ValueError("SoCConfig: lut_cluster entries out of range")
    if not np.isfinite(exec_t[np.arange(cfg.n_task_types), lut]).all():
        raise ValueError("SoCConfig: lut_cluster points a task type at a "
                         "cluster that cannot run it")
    if not (np.isfinite(cfg.us_per_kb) and cfg.us_per_kb >= 0):
        raise ValueError("SoCConfig: us_per_kb must be finite and >= 0")
    return cfg


def default_soc() -> SoCConfig:
    return SoCConfig()


def big_cluster_pes() -> Tuple[int, int]:
    """(start, count) of the Arm big cluster PEs (used by the DAS feature)."""
    return int(CLUSTER_PE_START[BIG]), PES_PER_CLUSTER[BIG]

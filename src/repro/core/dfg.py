"""Data-flow-graph models of the five streaming applications.

The paper evaluates range detection, temporal mitigation, WiFi-TX, WiFi-RX and
a proprietary industrial application (App-1). The public DS3 release models
these as small DAGs (5-35 tasks) of domain kernels. We reconstruct
representative graphs from the application structure described in the paper
and the DS3 publication; see DESIGN.md section 8 for the assumptions.

Each application is a list of (task_type, preds, out_kb) tuples; preds are
indices into the same list. Graphs are DAGs with a single sink is NOT required
(instance latency = max finish over its tasks).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import soc

T = {name: i for i, name in enumerate(soc.TASK_TYPE_NAMES)}

# (task_type_name, predecessor indices, output kilobytes)
_Spec = Tuple[str, Tuple[int, ...], float]


def _app(spec: Sequence[_Spec]) -> "AppGraph":
    types = np.array([T[s[0]] for s in spec], dtype=np.int32)
    n = len(spec)
    preds: List[Tuple[int, ...]] = [tuple(s[1]) for s in spec]
    out_kb = np.array([s[2] for s in spec], dtype=np.float32)
    for i, p in enumerate(preds):
        assert all(q < i for q in p), f"task {i}: preds must precede"
    return AppGraph(types, preds, out_kb)


@dataclasses.dataclass(frozen=True)
class AppGraph:
    task_types: np.ndarray          # [n] int32
    preds: List[Tuple[int, ...]]    # per-task predecessor indices
    out_kb: np.ndarray              # [n] float32, output payload per task

    @property
    def n_tasks(self) -> int:
        return int(self.task_types.shape[0])

    def depths(self) -> np.ndarray:
        d = np.zeros(self.n_tasks, dtype=np.int32)
        for i, p in enumerate(self.preds):
            d[i] = 0 if not p else 1 + max(d[q] for q in p)
        return d

    def succs(self) -> List[List[int]]:
        s: List[List[int]] = [[] for _ in range(self.n_tasks)]
        for i, p in enumerate(self.preds):
            for q in p:
                s[q].append(i)
        return s


# ---------------------------------------------------------------------------
# WiFi transmitter: scramble -> FEC encode -> interleave -> {QPSK -> pilot
# insertion -> IFFT} over four parallel OFDM symbol lanes -> frame assembly.
# ---------------------------------------------------------------------------
_witx: List[_Spec] = [
    ("scrambler",   (),    4.0),   # 0
    ("fec_enc",     (0,),  8.0),   # 1
    ("interleaver", (1,),  8.0),   # 2
]
for _lane in range(4):
    b = len(_witx)
    _witx.append(("qpsk_mod",     (2,),     4.0))
    _witx.append(("pilot_insert", (b,),     4.0))
    _witx.append(("ifft",         (b + 1,), 8.0))
_witx.append(("sync", tuple(5 + 3 * k for k in range(4)), 2.0))  # assembly
WIFI_TX = _app(_witx)

# ---------------------------------------------------------------------------
# WiFi receiver: sync -> {FFT -> demod} over four symbol lanes ->
# deinterleave -> FEC decode (viterbi) -> descramble.
# ---------------------------------------------------------------------------
_wirx: List[_Spec] = [("sync", (), 8.0)]  # 0 payload detect / CFO
for _lane in range(4):
    b = len(_wirx)
    _wirx.append(("fft",   (0,),  8.0))
    _wirx.append(("demod", (b,),  4.0))
_wirx.append(("interleaver", tuple(2 + 2 * k for k in range(4)), 8.0))
_wirx.append(("fec_dec", (len(_wirx) - 1,), 8.0))
_wirx.append(("scrambler", (len(_wirx) - 1,), 4.0))
WIFI_RX = _app(_wirx)

# ---------------------------------------------------------------------------
# Range detection (pulse-doppler radar): reference + received FFT, conjugate
# multiply (on SAP), IFFT, magnitude + detection on CPU.
# ---------------------------------------------------------------------------
RANGE_DETECTION = _app([
    ("sync",    (),      8.0),   # 0  waveform gen / capture
    ("fft",     (0,),    8.0),   # 1  received
    ("fft",     (0,),    8.0),   # 2  reference
    ("matmul",  (1, 2),  8.0),   # 3  conj multiply
    ("ifft",    (3,),    8.0),   # 4
    ("demod",   (4,),    2.0),   # 5  magnitude + peak detect
])

# ---------------------------------------------------------------------------
# Temporal mitigation (interference cancellation): FIR filter banks feeding a
# systolic projection, second FIR pass, decision.
# ---------------------------------------------------------------------------
TEMPORAL_MITIGATION = _app([
    ("sync",    (),      8.0),   # 0
    ("fir",     (0,),    8.0),   # 1
    ("fir",     (0,),    8.0),   # 2
    ("matmul",  (1, 2),  8.0),   # 3  correlation
    ("matmul",  (3,),    8.0),   # 4  projection
    ("fir",     (4,),    8.0),   # 5
    ("fir",     (4,),    8.0),   # 6
    ("demod",   (5, 6),  2.0),   # 7
])

# ---------------------------------------------------------------------------
# App-1: proprietary industrial app; per the paper it is the largest,
# FFT/FIR-heavy radar-like pipeline. Modeled as a 4-channel pipeline with a
# matmul fusion stage, 21 tasks.
# ---------------------------------------------------------------------------
_app1_spec: List[_Spec] = [("sync", (), 16.0)]  # 0
for ch in range(4):                              # 4 channels x (fir->fft->fir)
    b = len(_app1_spec)
    _app1_spec.append(("fir", (0,), 8.0))        # b
    _app1_spec.append(("fft", (b,), 8.0))        # b+1
    _app1_spec.append(("fir", (b + 1,), 8.0))    # b+2
_fuse_preds = tuple(3 + 3 * ch for ch in range(4))  # last fir of each channel
_app1_spec.append(("matmul", _fuse_preds, 16.0))     # 13 fusion
_f = len(_app1_spec) - 1
_app1_spec.append(("matmul", (_f,), 16.0))           # 14 beamform
_app1_spec.append(("ifft", (_f + 1,), 8.0))          # 15
_app1_spec.append(("fec_enc", (_f + 2,), 8.0))       # 16 telemetry encode
_app1_spec.append(("qpsk_mod", (_f + 3,), 4.0))      # 17
_app1_spec.append(("ifft", (_f + 4,), 8.0))          # 18
_app1_spec.append(("sync", (_f + 5,), 2.0))          # 19
APP_1 = _app(_app1_spec)

APPS: Dict[str, AppGraph] = {
    "wifi_tx": WIFI_TX,
    "wifi_rx": WIFI_RX,
    "range_detection": RANGE_DETECTION,
    "temporal_mitigation": TEMPORAL_MITIGATION,
    "app_1": APP_1,
}
APP_NAMES: Tuple[str, ...] = tuple(APPS.keys())
N_APPS = len(APP_NAMES)
MAX_APP_TASKS = max(a.n_tasks for a in APPS.values())
MAX_PREDS = max(max((len(p) for p in a.preds), default=0) for a in APPS.values())
MAX_SUCCS = max(
    max((len(s) for s in a.succs()), default=0) for a in APPS.values()
)
MAX_ROOTS = max(
    sum(1 for p in a.preds if not p) for a in APPS.values()
)

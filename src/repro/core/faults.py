"""Fault-injection plans for the DSSoC simulator (DS3/CEDR-style dynamics).

A `FaultPlan` describes everything that can go wrong during one scenario,
as pure JAX-compatible arrays so plans batch along the scenario axis
exactly like `tree` / `rate_threshold` in `simulator.simulate_batch`:

  * **permanent PE failures** — PE `p` is unavailable during
    `[pe_fail_at[p], pe_repair_at[p])`. Schedulers never place work on a
    dead PE; at the failure instant every in-flight assignment on the PE
    is revoked (killed) and re-enqueued.
  * **transient faults** — at each finite `transient_at[p, k]` the PE
    glitches: assignments made before that instant are killed and
    re-enqueued, but the PE stays available.
  * **cluster slowdown** — `cluster_slowdown[c]` (>= 1) multiplies the
    execution time of every task run on cluster `c` (DVFS / thermal
    throttling). Energy scales with the stretched time.
  * **retry budget** — a task killed by a fault is re-enqueued at the
    FIFO tail at most `max_retries` times; the next kill drops its whole
    job (application instance).
  * **per-job deadline** — an application instance still incomplete
    `deadline_us` after its arrival is dropped: all of its unfinished
    tasks are cancelled and counted, instead of the simulator spinning
    toward the `stalled` guard.

Degradation semantics in the simulator (`simulator.py`, mirrored by the
host-side reference `ref_sim.py`):

  * the LUT (fast) scheduler falls back to the most energy-efficient
    *healthy* cluster that can run the task type — when an accelerator
    cluster is fully dead, accelerated tasks degrade to the CPU clusters
    (which can run everything);
  * ETF masks dead PEs out of its earliest-finish-time search;
  * a decision is only taken when the chosen scheduler has a feasible
    (task, PE) pair; otherwise simulated time advances to the next event
    (including repairs, fault instants and job deadlines).

`healthy_plan()` is the identity: threading it through the simulator is
bit-identical to running without a plan.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import soc

# transient-fault slots per PE (finite entries are events, inf = unused)
MAX_TRANSIENTS = 4

_INF = np.float32(np.inf)


class FaultPlan(NamedTuple):
    """Per-scenario fault schedule (pure arrays; batch with a leading axis).

    All times are simulated microseconds. `inf` means "never".
    """

    pe_fail_at: jax.Array       # [P] f32 permanent-failure time
    pe_repair_at: jax.Array     # [P] f32 repair time (inf = never repaired)
    transient_at: jax.Array     # [P, MAX_TRANSIENTS] f32 glitch times
    cluster_slowdown: jax.Array  # [C] f32 exec-time multiplier (>= 1)
    max_retries: jax.Array      # [] i32 per-task kill->re-enqueue budget
    deadline_us: jax.Array      # [] f32 per-job deadline after arrival


def healthy_plan(n_pes: int = soc.N_PES,
                 n_clusters: int = soc.N_CLUSTERS) -> FaultPlan:
    """The no-fault identity plan (everything healthy forever)."""
    return FaultPlan(
        pe_fail_at=np.full(n_pes, _INF, np.float32),
        pe_repair_at=np.full(n_pes, _INF, np.float32),
        transient_at=np.full((n_pes, MAX_TRANSIENTS), _INF, np.float32),
        cluster_slowdown=np.ones(n_clusters, np.float32),
        max_retries=np.int32(0),
        deadline_us=np.float32(_INF),
    )


# ---------------------------------------------------------------------------
# plan builders (host-side, numpy in / numpy out)
# ---------------------------------------------------------------------------
def _np_plan(plan: FaultPlan) -> FaultPlan:
    return FaultPlan(*[np.array(x) for x in plan])


def fail_pes(plan: FaultPlan, pes: Sequence[int], at: float,
             repair_at: float = float("inf")) -> FaultPlan:
    """Permanently fail `pes` at time `at` (optionally repaired later)."""
    p = _np_plan(plan)
    p.pe_fail_at[list(pes)] = np.float32(at)
    p.pe_repair_at[list(pes)] = np.float32(repair_at)
    return p


def fail_cluster(plan: FaultPlan, cluster: int, at: float,
                 repair_at: float = float("inf")) -> FaultPlan:
    """Fail every PE of `cluster` (see `soc.CLUSTER_NAMES`)."""
    pes = np.where(soc.PE_CLUSTER == cluster)[0]
    return fail_pes(plan, pes, at, repair_at=repair_at)


def add_transient(plan: FaultPlan, pe: int, at: float) -> FaultPlan:
    """Add one transient glitch on `pe` at time `at` (kills in-flight work)."""
    p = _np_plan(plan)
    row = p.transient_at[pe]
    free = np.where(~np.isfinite(row))[0]
    if free.size == 0:
        raise ValueError(
            f"PE {pe} already has {MAX_TRANSIENTS} transient faults")
    row[free[0]] = np.float32(at)
    return p


def slow_cluster(plan: FaultPlan, cluster: int, factor: float) -> FaultPlan:
    """Throttle `cluster` by `factor` (>= 1; DVFS/thermal slowdown)."""
    p = _np_plan(plan)
    p.cluster_slowdown[cluster] = np.float32(factor)
    return p


def with_retries(plan: FaultPlan, max_retries: int) -> FaultPlan:
    p = _np_plan(plan)
    return p._replace(max_retries=np.int32(max_retries))


def with_deadline(plan: FaultPlan, deadline_us: float) -> FaultPlan:
    p = _np_plan(plan)
    return p._replace(deadline_us=np.float32(deadline_us))


def random_plan(seed: int, n_fail: int = 2, n_transient: int = 4,
                t_horizon_us: float = 200.0,
                max_retries: int = 2,
                deadline_us: float = float("inf"),
                n_pes: int = soc.N_PES) -> FaultPlan:
    """A seeded adversarial plan: `n_fail` permanent failures (half of them
    repaired) plus `n_transient` transient glitches inside the horizon."""
    rng = np.random.RandomState(seed)
    plan = with_deadline(with_retries(healthy_plan(), max_retries),
                         deadline_us)
    fail = rng.choice(n_pes, size=min(n_fail, n_pes), replace=False)
    for j, pe in enumerate(fail):
        at = float(rng.uniform(0.0, t_horizon_us))
        rep = at + float(rng.uniform(0.2, 1.0) * t_horizon_us) \
            if j % 2 == 0 else float("inf")
        plan = fail_pes(plan, [int(pe)], at, repair_at=rep)
    for _ in range(n_transient):
        plan = add_transient(plan, int(rng.randint(n_pes)),
                             float(rng.uniform(0.0, t_horizon_us)))
    return plan


def stack_plans(plans: Sequence[FaultPlan]) -> FaultPlan:
    """Stack same-shape plans into a leading scenario axis (for
    `simulate_batch` sweeps, mirroring `workloads.stack_workloads`)."""
    if not plans:
        raise ValueError("stack_plans: need at least one plan")
    return FaultPlan(*[
        np.stack([np.asarray(f) for f in fields]) for fields in zip(*plans)
    ])


def is_batched(plan: FaultPlan) -> bool:
    """True when the plan carries a leading scenario axis."""
    return np.ndim(plan.pe_fail_at) == 2


# capability flags threaded as a *static* jit argument: the simulator
# skips tracing fault phases the plan can statically never fire
NO_CAPS = (False, False, False)
FULL_CAPS = (True, True, True)


def plan_capabilities(plan: FaultPlan) -> tuple:
    """`(can_die, can_kill, has_deadline)` — which fault phases this plan
    can ever fire, decidable host-side from the concrete arrays.

    * `can_die` — some PE has a finite failure window, so availability
      masks / feasibility checks matter.
    * `can_kill` — some kill instant (permanent failure or transient
      glitch) is finite AND strictly positive. A kill at `tau` revokes
      only assignments with `assign_t < tau`, and assignments happen at
      `now >= 0`, so `tau <= 0` can never revoke anything — the common
      fail-everything-at-t=0 degradation sweeps skip the whole
      kill/retry/drop machinery per step.
    * `has_deadline` — `deadline_us` is finite somewhere, so the
      deadline-drop phase can fire.

    The simulator traces one specialization per distinct tuple; gated-off
    phases are exact no-ops (their `due` predicate is identically False),
    so results are bit-identical to the fully-traced path.
    """
    fail = np.asarray(plan.pe_fail_at)
    trans = np.asarray(plan.transient_at)
    dl = np.asarray(plan.deadline_us)
    can_die = bool(np.isfinite(fail).any())
    can_kill = bool((np.isfinite(fail) & (fail > 0)).any()
                    or (np.isfinite(trans) & (trans > 0)).any())
    has_deadline = bool(np.isfinite(dl).any())
    return can_die, can_kill, has_deadline


def validate_plan(plan: FaultPlan, n_pes: int = soc.N_PES,
                  n_clusters: int = soc.N_CLUSTERS) -> FaultPlan:
    """Host-side sanity checks; raises ValueError on malformed plans."""
    p = FaultPlan(*[np.asarray(x) for x in plan])
    lead = p.pe_fail_at.shape[:-1]
    if p.pe_fail_at.shape[-1] != n_pes or p.pe_repair_at.shape[-1] != n_pes:
        raise ValueError(
            f"FaultPlan: per-PE arrays must have trailing dim {n_pes}, got "
            f"{p.pe_fail_at.shape} / {p.pe_repair_at.shape}")
    if p.transient_at.shape[-2:] != (n_pes, MAX_TRANSIENTS) \
            or p.transient_at.shape[:-2] != lead:
        raise ValueError(
            f"FaultPlan: transient_at must end in ({n_pes}, "
            f"{MAX_TRANSIENTS}), got {p.transient_at.shape}")
    if p.cluster_slowdown.shape[-1] != n_clusters:
        raise ValueError(
            f"FaultPlan: cluster_slowdown must have trailing dim "
            f"{n_clusters}, got {p.cluster_slowdown.shape}")
    for name in ("pe_fail_at", "pe_repair_at", "transient_at", "deadline_us"):
        v = getattr(p, name)
        if np.isnan(v).any() or (v < 0).any():
            raise ValueError(f"FaultPlan.{name}: times must be >= 0, no NaN")
    if (p.pe_repair_at < p.pe_fail_at).any():
        raise ValueError("FaultPlan: pe_repair_at must be >= pe_fail_at")
    if np.isnan(p.cluster_slowdown).any() or (p.cluster_slowdown < 1.0).any():
        raise ValueError("FaultPlan: cluster_slowdown must be >= 1.0")
    if (p.max_retries < 0).any():
        raise ValueError("FaultPlan: max_retries must be >= 0")
    return plan


# ---------------------------------------------------------------------------
# jnp helpers shared by the jitted simulator
# ---------------------------------------------------------------------------
def alive_at(plan: FaultPlan, now) -> jax.Array:
    """[P] bool availability mask at time `now` (dead inside
    `[fail_at, repair_at)`)."""
    return ~((plan.pe_fail_at <= now) & (now < plan.pe_repair_at))


def pe_slowdown(plan: FaultPlan, pe_cluster: jax.Array) -> jax.Array:
    """[P] per-PE exec-time multiplier from the cluster slowdown vector."""
    return plan.cluster_slowdown[pe_cluster]


def kill_times(plan: FaultPlan) -> jax.Array:
    """[P, 1 + MAX_TRANSIENTS] every instant that revokes in-flight
    assignments on a PE (permanent failure + transient glitches)."""
    return jnp.concatenate(
        [plan.pe_fail_at[:, None], plan.transient_at], axis=1)

"""DAS: end-to-end training + deployment of the preselection classifier.

`train_das` generates the oracle dataset, fits the depth-2 decision tree on
the paper's two features (input data rate + earliest big-cluster
availability), and returns a deployable `DASPolicy` whose `tree` plugs into
the simulator (MODE_DAS) or the serving dispatcher.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core import classifier as clf
from repro.core import oracle
from repro.core import simulator as sim
from repro.core.simulator import FEAT_BIG_AVAIL, FEAT_RATE
from repro.core.workloads import WorkloadSuite

PAPER_FEATURES = (FEAT_RATE, FEAT_BIG_AVAIL)


@dataclasses.dataclass
class DASPolicy:
    tree: sim.DTree                 # depth-2, simulator-ready
    dtree: clf.DecisionTree         # host-side classifier
    feature_ids: Sequence[int]
    train_accuracy: float
    test_accuracy: float
    n_train: int

    def run(self, wl, params=None, plan=None) -> sim.SimResult:
        """Simulate this policy on `wl`; `plan` is an optional
        `faults.FaultPlan` for fault-injection runs."""
        params = params or sim.make_params()
        return sim.run(sim.MODE_DAS, wl, params, tree=self.tree, plan=plan)


def fit_policy(ds: oracle.OracleDataset,
               feature_ids: Sequence[int] = PAPER_FEATURES,
               depth: int = 2,
               test_frac: float = 0.25,
               seed: int = 0) -> DASPolicy:
    tr, te = oracle.train_test_split(ds, test_frac=test_frac, seed=seed)
    cols = list(feature_ids)
    tree = clf.DecisionTree.fit(tr.features[:, cols], tr.labels, depth=depth,
                                feature_ids=cols)
    return DASPolicy(
        tree=tree.to_depth2_arrays(),
        dtree=tree,
        feature_ids=cols,
        train_accuracy=tree.accuracy(tr.features[:, cols], tr.labels),
        test_accuracy=tree.accuracy(te.features[:, cols], te.labels),
        n_train=len(tr),
    )


def train_das(suite: WorkloadSuite,
              params: sim.SimParams | None = None,
              mix_indices: Iterable[int] | None = None,
              rate_indices: Iterable[int] | None = None,
              metric: str = "avg_exec_us",
              feature_ids: Sequence[int] = PAPER_FEATURES,
              verbose: bool = False,
              batch_size: int | None = None) -> DASPolicy:
    """End-to-end DAS training; the oracle pass runs the whole
    (mix x rate) grid through the batched simulator (`batch_size` chunks
    the scenario axis, see `oracle.generate`)."""
    params = params or sim.make_params()
    ds = oracle.generate(suite, params, mix_indices=mix_indices,
                         rate_indices=rate_indices, metric=metric,
                         verbose=verbose, batch_size=batch_size)
    return fit_policy(ds, feature_ids=feature_ids)

"""Jittable discrete-event DSSoC simulator (DS3-style) in pure JAX.

One `lax.while_loop` iteration handles exactly one of, in priority order:
  1. a task completion whose finish time is due (finish <= now),
  2. a frame (application-instance) arrival that is due,
  3. one scheduling decision if the ready queue is non-empty,
  4. otherwise advance simulated time to the next event.

Scheduling overhead is modeled faithfully to the paper: the scheduler is a
serial resource (`sched_free`); each decision occupies it for the policy's
latency and burns the policy's energy; a scheduled task cannot start before
its decision completes.

Modes
-----
  MODE_LUT        fast scheduler only (paper's F)
  MODE_ETF        slow scheduler only (paper's S, Algorithm 1)
  MODE_ETF_IDEAL  ETF with zero scheduling overhead (paper's ETF-ideal)
  MODE_DAS        depth-2 decision tree preselects F or S per decision
  MODE_ORACLE     run both schedulers per decision, follow F, log agreement
                  (paper's "first execution" for oracle generation)
  MODE_THRESHOLD  static data-rate threshold picks F or S (paper's heuristic)

The whole simulation jits; `simulate` is wrapped in `jax.jit` with the mode
and capacity constants static.

Batched sweeps
--------------
The (workload-mix x data-rate) grids behind the paper's Fig. 2 / Table 2 /
40-workload summary all run the same jitted loop over same-shape workloads,
so the scenario axis vmaps: `stack_workloads` (workloads.py) stacks a suite's
`FlatWorkload`s into a leading axis and `simulate_batch` / `run_batch` map
`simulate` over it (`SimParams` held constant; `tree` / `rate_threshold`
optionally per-scenario for DAS / threshold sweeps). Every `SimResult` field
gains a leading scenario axis; `result_at` slices one scenario back out.
`run_batch` additionally chunks the axis into fixed-shape, padded chunks
(one compiled executable per sweep), shards each chunk across devices
(`devices=` / `REPRO_BENCH_DEVICES`, see DESIGN.md "Sharded sweeps") and
streams all chunks through the device queue before one blocking fetch.

Fault injection and graceful degradation
----------------------------------------
Passing a `faults.FaultPlan` (`plan=` on `simulate` / `run` / `run_batch`)
threads a fault model through the same event loop, adding three event
classes between completions and arrivals:

  kill      a permanent PE failure or transient glitch revokes every
            assignment made on that PE before the fault instant; the task
            re-enters the FIFO tail (bounded by `plan.max_retries`, after
            which its whole job is dropped),
  deadline  a job (application instance) still incomplete `deadline_us`
            after its arrival is dropped with full accounting instead of
            spinning toward the `stalled` guard,
  drop      (inside kill/deadline) cancels every unfinished task of a job
            and purges them from the ready queue.

Schedulers degrade rather than fail: the LUT falls back to the most
energy-efficient *healthy* cluster for the task type (accelerated tasks
degrade to the CPU clusters when their accelerator is fully dead), ETF
masks dead PEs out of its earliest-finish-time search, and a decision is
only taken when the chosen scheduler has a feasible (task, PE) pair —
otherwise time advances to the next event, which now includes repairs,
fault instants and job deadlines. Cluster slowdown factors stretch the
cached exec rows at ready-queue push time.

`plan=None` (the default) traces the exact pre-fault computation — zero
overhead and bit-identical results — and `plan=faults.healthy_plan()`
runs the fault path with nothing failing, which the tests assert is also
bit-identical. Batched sweeps accept a plan with a leading scenario axis
(`faults.stack_plans`), batching fault scenarios like `tree` /
`rate_threshold`.
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec

try:  # jax >= 0.4.x; pmap fallback below when absent
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # pragma: no cover
    _shard_map = None

from repro.core import faults as flt
from repro.core import soc
from repro.core.workloads import FlatWorkload, FRAME_KBITS
from repro.kernels.etf_ft import ops as _kops

MODE_LUT = 0
MODE_ETF = 1
MODE_ETF_IDEAL = 2
MODE_DAS = 3
MODE_ORACLE = 4
MODE_THRESHOLD = 5

MODE_NAMES = {
    MODE_LUT: "LUT",
    MODE_ETF: "ETF",
    MODE_ETF_IDEAL: "ETF-ideal",
    MODE_DAS: "DAS",
    MODE_ORACLE: "oracle",
    MODE_THRESHOLD: "threshold",
}

# Ready-queue capacity (compact buffer). The queue fully drains before
# simulated time advances (decisions outrank the advance branch), so depth
# is bounded by simultaneous task releases, not workload size — measured
# max is 12 across the 40x14 suite at 60 instances. 16 leaves headroom and
# keeps the per-decision [R, MP, P] availability tensor small;
# `ready_drop` counts overflows and the tests assert it stays 0.
R_MAX = 16
SEG = 32            # fin_run segment size for the two-level next-completion
#   search: `fin_seg[k] == fin_run[k*SEG:(k+1)*SEG].min()` is maintained
#   incrementally, so the hot loop reduces over [T/SEG] instead of [T].
RING = 8            # data-rate shift register entries (paper: 8x16bit)
N_FEATURES = 62     # performance-counter feature bank size (paper Table I)
_INF = jnp.float32(jnp.inf)
_NEG = jnp.float32(-jnp.inf)


class SimParams(NamedTuple):
    """Device-side hardware tables (from `soc.SoCConfig`)."""

    exec_pe: jax.Array        # [n_types, P] f32 (inf = cannot run)
    pe_cluster: jax.Array     # [P] i32
    pe_power: jax.Array       # [P] f32
    lut_cluster: jax.Array    # [n_types] i32
    cluster_pe_mask: jax.Array  # [C, P] bool
    us_per_kb: jax.Array      # [] f32
    cluster_energy: jax.Array  # [n_types, C] f32 (inf = cannot run); ranks
    #   the LUT's per-type fallback order when clusters die.


def make_params(cfg: soc.SoCConfig | None = None) -> SimParams:
    cfg = cfg or soc.default_soc()
    soc.validate_config(cfg)
    return SimParams(
        exec_pe=jnp.asarray(cfg.exec_on_pe()),
        pe_cluster=jnp.asarray(cfg.pe_cluster),
        pe_power=jnp.asarray(cfg.cluster_power[cfg.pe_cluster]),
        lut_cluster=jnp.asarray(cfg.lut_cluster),
        cluster_pe_mask=jnp.asarray(cfg.cluster_pe_mask),
        us_per_kb=jnp.float32(cfg.us_per_kb),
        cluster_energy=jnp.asarray(cfg.task_energy),
    )


class DTree(NamedTuple):
    """Depth-2 decision tree over the feature vector (3 internal nodes).

    node 0 is the root; node 1 is the left child (feature < thr), node 2 the
    right child. Leaves: [LL, LR, RL, RR], value 1 => use the slow scheduler.
    """

    feat: jax.Array    # [3] i32 feature indices
    thr: jax.Array     # [3] f32 thresholds
    leaf: jax.Array    # [4] i32 in {0, 1}

    def predict(self, f: jax.Array) -> jax.Array:
        right0 = f[self.feat[0]] >= self.thr[0]
        node = jnp.where(right0, 2, 1)
        rightc = f[self.feat[node]] >= self.thr[node]
        idx = jnp.where(right0, 2, 0) + rightc.astype(jnp.int32)
        return self.leaf[idx]


def always_fast_tree() -> DTree:
    return DTree(feat=jnp.zeros(3, jnp.int32), thr=jnp.full(3, jnp.inf),
                 leaf=jnp.zeros(4, jnp.int32))


class SimState(NamedTuple):
    now: jax.Array          # [] f32
    stalled: jax.Array      # [] bool no event can ever become due again
    sched_free: jax.Array   # [] f32 scheduler-core availability
    arr_ptr: jax.Array      # [] i32 next instance to arrive
    n_done: jax.Array       # [] i32
    n_sched: jax.Array      # [] i32 tasks scheduled so far
    status: jax.Array       # [T] i8 0=waiting 2=ready 3=running 4=done
    pred_rem: jax.Array     # [T] i32
    start: jax.Array        # [T] f32
    finish: jax.Array       # [T] f32 (inf until scheduled)
    fin_run: jax.Array      # [Tp] f32 finish while running, else inf.
    #   Incremental mirror of `where(status == 3, finish, inf)` so the hot
    #   loop finds the next completion without rebuilding the mask from
    #   status/finish. Padded to Tp = ceil(T/SEG)*SEG with inf.
    fin_seg: jax.Array      # [Tp/SEG] f32 per-segment min of fin_run.
    #   Invariant: fin_seg[k] == fin_run[k*SEG:(k+1)*SEG].min(); updated by
    #   a scatter-min on assign and a SEG-sized rescan on completion, so
    #   finding the next completion scans [Tp/SEG] + [SEG], not [T].
    n_running: jax.Array    # [] i32 count of status==3 tasks
    pe_of: jax.Array        # [T] i32 (-1 until scheduled)
    pe_free: jax.Array      # [P] f32
    pe_busy: jax.Array      # [P] f32 accumulated busy time
    ready_ids: jax.Array    # [R_MAX] i32 FIFO, -1 = empty
    ready_cnt: jax.Array    # [] i32
    ready_drop: jax.Array   # [] i32 overflow counter (should stay 0)
    ready_avail: jax.Array  # [R_MAX, P] f32 cached availability-with-comm
    #   rows, computed once at push time (`_avail_rows`): a ready task's
    #   preds are all finished, so its availability per PE never changes.
    ready_exec: jax.Array   # [R_MAX, P] f32 cached exec_pe rows.
    #   Rows at slots >= ready_cnt are stale garbage; every consumer masks
    #   on `ready_ids >= 0`.
    task_energy: jax.Array  # [] f32 uJ
    sched_energy: jax.Array  # [] f32 uJ
    sched_time: jax.Array   # [] f32 us of scheduler occupancy
    n_fast: jax.Array       # [] i32
    n_slow: jax.Array       # [] i32
    ring: jax.Array         # [RING] f32 last arrival timestamps
    ring_ptr: jax.Array     # [] i32
    arr_count: jax.Array    # [] i32
    # decision logs (capacity T)
    d_ptr: jax.Array        # [] i32
    log_feat: jax.Array     # [T, N_FEATURES] f32
    log_policy: jax.Array   # [T] i8 (0 fast, 1 slow)
    log_agree: jax.Array    # [T] i8 (oracle: fast/slow decisions identical)
    log_task: jax.Array     # [T] i32
    # fault / degradation state (written only when a FaultPlan is threaded;
    # status gains 5 = dropped with its job)
    pe_alive: jax.Array     # [P] bool live availability mask (refreshed from
    #   the plan's fail/repair windows whenever `now` moves)
    pe_slow: jax.Array      # [P] f32 exec-time multiplier (throttling)
    assign_t: jax.Array     # [T] f32 decision time of the live assignment;
    #   a fault at time tau only revokes assignments with assign_t < tau
    retries: jax.Array      # [T] i32 fault-kill count per task
    kill_t: jax.Array       # [T] f32 time of the last kill (recovery base)
    inst_rem: jax.Array     # [I] i32 unfinished tasks per instance
    job_dropped: jax.Array  # [I] bool instance was dropped
    n_kills: jax.Array      # [] i32 fault events that revoked an assignment
    n_retries: jax.Array    # [] i32 kills that re-enqueued (vs dropped)
    reexec_us: jax.Array    # [] f32 executed work revoked then redone
    n_dropped_tasks: jax.Array  # [] i32
    recovery_us: jax.Array  # [] f32 sum over recovered tasks of
    #   (final finish - last kill time)
    n_recovered: jax.Array  # [] i32 killed tasks that eventually finished


class SimResult(NamedTuple):
    avg_exec_us: jax.Array     # [] f32 mean instance latency
    makespan_us: jax.Array     # [] f32
    total_energy_uj: jax.Array  # [] f32 (task + scheduling energy)
    task_energy_uj: jax.Array
    sched_energy_uj: jax.Array
    sched_time_us: jax.Array
    edp: jax.Array             # [] f32 total energy * avg exec time
    n_decisions: jax.Array     # [] i32
    n_fast: jax.Array
    n_slow: jax.Array
    n_done: jax.Array
    ready_drop: jax.Array
    n_iters: jax.Array         # [] i32 while-loop iterations consumed
    stalled: jax.Array         # [] bool sim gave up (unschedulable tasks)
    inst_exec_us: jax.Array    # [I] f32 per-instance latency (inf = invalid)
    # oracle / analysis logs
    log_feat: jax.Array
    log_policy: jax.Array
    log_agree: jax.Array
    log_task: jax.Array
    finish: jax.Array          # [T] f32
    pe_of: jax.Array           # [T] i32
    # fault / degradation accounting (all zero without a FaultPlan)
    n_faults: jax.Array        # [] i32 kill events (assignment revocations)
    n_retries: jax.Array       # [] i32 kills that re-enqueued the task
    reexec_us: jax.Array       # [] f32 executed work revoked then redone
    n_dropped_jobs: jax.Array  # [] i32 instances dropped (deadline / retries)
    n_dropped_tasks: jax.Array  # [] i32 tasks cancelled with their job
    recovery_us: jax.Array     # [] f32 sum of (finish - last kill) over
    #   killed tasks that eventually completed
    n_recovered: jax.Array     # [] i32 killed tasks that completed anyway
    job_dropped: jax.Array     # [I] bool per-instance drop flags
    # stall diagnostics (appended last: fields[:21] are the stable
    # pre-fault prefix other code indexes by position)
    stall_reason: jax.Array    # [] i32 STALL_NONE / STALL_DEADLOCK /
    #   STALL_BUDGET (iteration cap or `step_budget` hit before draining)


# `SimResult.stall_reason` values
STALL_NONE = 0      # drained the workload (or dropped the remainder)
STALL_DEADLOCK = 1  # no event can ever become due again (`stalled` flag)
STALL_BUDGET = 2    # hit `max_iters` / `step_budget` with work remaining


# ---------------------------------------------------------------------------
# feature bank (paper Table I: task / PE / system counters, 62 total)
# ---------------------------------------------------------------------------
def _features(p: SimParams, wl: FlatWorkload, s: SimState) -> jax.Array:
    now = s.now
    cnt = jnp.minimum(s.arr_count, RING)
    oldest = jnp.where(
        s.arr_count >= RING, s.ring[s.ring_ptr % RING],
        s.ring[0],
    )
    newest = s.ring[(s.ring_ptr - 1) % RING]
    span = jnp.maximum(newest - oldest, 1e-3)
    rate_est = jnp.where(
        cnt >= 2,
        (cnt - 1).astype(jnp.float32) * FRAME_KBITS * 1000.0 / span,
        0.0,
    )  # Mbps

    pe_avail = jnp.maximum(s.pe_free - now, 0.0)              # [P]
    cl_avail = jnp.where(
        p.cluster_pe_mask, pe_avail[None, :], _INF
    ).min(axis=1)                                             # [C]
    util = s.pe_busy / jnp.maximum(now, 1e-3)                 # [P]

    head = s.ready_ids[0]
    head_ok = head >= 0
    h = jnp.maximum(head, 0)
    htype = wl.task_type[h]
    hpreds = wl.preds[h]                                      # [MP]
    hvalid = jnp.arange(hpreds.shape[0]) < wl.n_preds[h]
    pred_cl = jnp.where(
        hvalid & (hpreds >= 0),
        p.pe_cluster[jnp.maximum(s.pe_of[jnp.maximum(hpreds, 0)], 0)],
        -1,
    )
    pred_cl = jnp.pad(pred_cl, (0, max(0, 4 - pred_cl.shape[0])),
                      constant_values=-1)[:4]
    lut_cl = p.lut_cluster[htype]
    lut_pe = p.cluster_pe_mask[lut_cl].argmax()   # first PE of LUT cluster

    def z(x):
        return jnp.where(head_ok, x.astype(jnp.float32), 0.0)

    feats = jnp.concatenate([
        jnp.array([rate_est, s.ready_cnt.astype(jnp.float32)]),
        cl_avail,                                  # 6
        pe_avail,                                  # 19
        util,                                      # 19
        jnp.array([
            z(htype), z(wl.depth[h]), z(wl.app_id[h]), z(wl.out_kb[h]),
            z(p.exec_pe[htype, 0]),                        # exec on big
            z(p.exec_pe[htype, lut_pe]),                   # exec on LUT PE
            z(p.exec_pe[htype, lut_pe] * p.pe_power[lut_pe]),
            z(wl.n_preds[h]),
        ]),
        pred_cl.astype(jnp.float32),               # 4
        jnp.array([
            jnp.maximum(s.sched_free - now, 0.0),
            s.arr_count.astype(jnp.float32),
            s.n_done.astype(jnp.float32)
            / jnp.maximum(wl.n_tasks.astype(jnp.float32), 1.0),
            s.n_running.astype(jnp.float32),
        ]),
    ])
    assert feats.shape == (N_FEATURES,), feats.shape
    return feats


FEAT_RATE = 0           # input data rate (paper's #1 feature)
FEAT_BIG_AVAIL = 2      # earliest availability of the big cluster (#2)
FEAT_NAMES = (
    ["input_data_rate", "ready_queue_len"]
    + [f"cluster_avail_{c}" for c in soc.CLUSTER_NAMES]
    + [f"pe_avail_{i}" for i in range(soc.N_PES)]
    + [f"pe_util_{i}" for i in range(soc.N_PES)]
    + ["head_type", "head_depth", "head_app", "head_out_kb",
       "head_exec_big", "head_exec_lut", "head_energy_lut", "head_n_preds"]
    + [f"head_pred_cluster_{k}" for k in range(4)]
    + ["sched_backlog", "arrivals_so_far", "done_frac", "running_count"]
)


# ---------------------------------------------------------------------------
# scheduler decision helpers
# ---------------------------------------------------------------------------
def _avail_rows(p: SimParams, wl: FlatWorkload, s: SimState,
                tasks: jax.Array, bases: jax.Array,
                kmode: str = "off") -> jax.Array:
    """[K, P] availability (incl. NoC transfer from pred clusters).

    Evaluated once per task at push time: a task enters the ready queue
    only when every predecessor has finished, so pred finish times, pred
    placements, and hence this whole row are constants from then on. The
    rows are cached in `SimState.ready_avail` — recomputing the [R, MP, P]
    tensor at every decision was the single hottest part of the batched
    sweep loop. With `kmode != "off"` the [K, MP, P] contribution max
    routes through the fused push-time kernel (`kernels/etf_ft/ops.py`),
    bitwise identical to the inline tensor.
    """
    t = jnp.maximum(tasks, 0)                       # [K]
    preds = wl.preds[t]                             # [K, MP]
    pv = (jnp.arange(preds.shape[1])[None, :] < wl.n_preds[t][:, None])
    pidx = jnp.maximum(preds, 0)
    pfin = jnp.where(pv, s.finish[pidx], _NEG)      # [K, MP]
    pkb = jnp.where(pv, wl.out_kb[pidx], 0.0)
    pcl = p.pe_cluster[jnp.maximum(s.pe_of[pidx], 0)]          # [K, MP]
    if kmode != "off":
        return _kops.push_rows(pfin, pkb * p.us_per_kb, pcl, pv,
                               p.pe_cluster, bases,
                               p.cluster_pe_mask.shape[0], mode=kmode)
    cross = pcl[:, :, None] != p.pe_cluster[None, None, :]     # [K, MP, P]
    contrib = jnp.where(
        pv[:, :, None],
        pfin[:, :, None] + pkb[:, :, None] * p.us_per_kb * cross,
        _NEG,
    )                                               # [K, MP, P]
    return jnp.maximum(contrib.max(axis=1), bases[:, None])    # [K, P]


def _etf_choice(p: SimParams, wl: FlatWorkload, s: SimState,
                kmode: str = "off"):
    """Earliest-finish-time (task, pe) over the ready buffer (Algorithm 1).

    Pure lookup over the cached `ready_avail` / `ready_exec` rows. With
    `kmode != "off"` the masked finish-time search routes through the
    decision kernel (same first-global-minimum tie-break).
    """
    slot_ok = s.ready_ids >= 0                      # [R]
    if kmode != "off":
        slot, pe, _ = _kops.etf_decide(s.ready_avail, s.pe_free,
                                       s.ready_exec, s.now, slot_ok, None,
                                       mode=kmode)
        return slot, pe
    ft = jnp.maximum(jnp.maximum(s.ready_avail, s.pe_free[None, :]),
                     s.now) + s.ready_exec
    ft = jnp.where(slot_ok[:, None], ft, _INF)
    flat = jnp.argmin(ft)
    slot = flat // ft.shape[1]
    pe = flat % ft.shape[1]
    return slot.astype(jnp.int32), pe.astype(jnp.int32)


def _lut_choice(p: SimParams, wl: FlatWorkload, s: SimState):
    """Fast scheduler: FIFO head -> most-energy-efficient cluster -> its
    earliest-free PE."""
    slot = jnp.int32(0)
    t = jnp.maximum(s.ready_ids[0], 0)
    cl = p.lut_cluster[wl.task_type[t]]
    free = jnp.where(p.cluster_pe_mask[cl], s.pe_free, _INF)
    pe = jnp.argmin(free).astype(jnp.int32)
    return slot, pe


def _lut_choice_degraded(p: SimParams, wl: FlatWorkload, s: SimState):
    """Fault-aware fast scheduler: (slot, pe, feasible).

    Re-ranks clusters by `cluster_energy` restricted to clusters with at
    least one live PE, so a dead accelerator degrades to the next-best
    healthy cluster (ultimately the CPU clusters, which run every type).
    With every PE alive this reduces exactly to `_lut_choice`: the argmin
    over the full energy row *is* the precomputed `lut_cluster` entry
    (same table, same first-minimum tie-break).
    """
    slot = jnp.int32(0)
    t = jnp.maximum(s.ready_ids[0], 0)
    tt = wl.task_type[t]
    cl_alive = (p.cluster_pe_mask & s.pe_alive[None, :]).any(axis=1)  # [C]
    e = jnp.where(cl_alive, p.cluster_energy[tt], _INF)               # [C]
    cl = jnp.argmin(e).astype(jnp.int32)
    ok = (s.ready_ids[0] >= 0) & jnp.isfinite(e[cl])
    free = jnp.where(p.cluster_pe_mask[cl] & s.pe_alive, s.pe_free, _INF)
    pe = jnp.argmin(free).astype(jnp.int32)
    return slot, pe, ok


def _etf_choice_degraded(p: SimParams, wl: FlatWorkload, s: SimState,
                         kmode: str = "off"):
    """Fault-aware ETF: (slot, pe, feasible) with dead PEs masked out of
    the earliest-finish-time search. All-alive == `_etf_choice` exactly."""
    slot_ok = s.ready_ids >= 0                      # [R]
    if kmode != "off":
        return _kops.etf_decide(s.ready_avail, s.pe_free, s.ready_exec,
                                s.now, slot_ok, s.pe_alive, mode=kmode)
    ft = jnp.maximum(jnp.maximum(s.ready_avail, s.pe_free[None, :]),
                     s.now) + s.ready_exec
    ft = jnp.where(slot_ok[:, None] & s.pe_alive[None, :], ft, _INF)
    flat = jnp.argmin(ft)
    slot = flat // ft.shape[1]
    pe = flat % ft.shape[1]
    ok = jnp.isfinite(ft.reshape(-1)[flat])
    return slot.astype(jnp.int32), pe.astype(jnp.int32), ok


def _can_schedule(mode: int, p: SimParams, wl: FlatWorkload, s: SimState,
                  tree: DTree, rate_threshold: jax.Array,
                  kmode: str = "off") -> jax.Array:
    """Whether the scheduler the mode would invoke has a feasible
    (task, PE) pair under the current availability mask (fault path only).

    The fast path considers only the FIFO head, so a head whose every
    capable cluster is dead blocks the queue until a repair or its job's
    deadline drop — head-of-line blocking is part of the degradation
    model. ETF infeasible implies no ready task can run anywhere healthy.
    """
    if mode in (MODE_LUT, MODE_ORACLE):
        return _lut_choice_degraded(p, wl, s)[2]
    if mode in (MODE_ETF, MODE_ETF_IDEAL):
        return _etf_choice_degraded(p, wl, s, kmode)[2]
    # DAS / THRESHOLD: feasibility of the scheduler the policy will pick
    feats = _features(p, wl, s)
    if mode == MODE_DAS:
        use_slow = tree.predict(feats).astype(bool)
    else:
        use_slow = feats[FEAT_RATE] >= rate_threshold
    ok_f = _lut_choice_degraded(p, wl, s)[2]
    ok_s = _etf_choice_degraded(p, wl, s, kmode)[2]
    return jnp.where(use_slow, ok_s, ok_f)


# ---------------------------------------------------------------------------
# state mutations
#
# Each mutation takes an optional `active` gate. `active=None` means
# statically active (the `lax.switch` body, where the branch only runs when
# chosen). A traced `active` gates every update with `where`, which is how
# the batched (`masked=True`) body keeps one-event-per-iteration semantics
# without `lax.switch` — a vmapped switch executes all branches anyway and
# then pays a select over the whole carry (including the [T, F] logs) per
# branch per iteration, which dominated the sweep cost.
# ---------------------------------------------------------------------------
def _gate(active, new, old):
    return new if active is None else jnp.where(active, new, old)


def _gate_i(active) -> jax.Array:
    return jnp.int32(1) if active is None else active.astype(jnp.int32)


def _gset(active, arr, idx, val):
    """Gated row write: `arr[idx] = val` only when `active`.

    Inactive writes are redirected to an out-of-bounds row that
    `mode="drop"` discards, so the whole thing stays a one-row scatter
    XLA can apply in place on the loop carry. The alternative,
    `jnp.where(active, arr.at[idx].set(val), arr)`, materializes a
    full-array select per call — ruinous for the [T, F] decision log
    inside the batched while loop.
    """
    if active is None:
        return arr.at[idx].set(val)
    oob = jnp.where(active, idx, arr.shape[0])
    return arr.at[oob].set(val, mode="drop")


def _gadd(active, arr, idx, val):
    """Gated `arr[idx] += val` (same out-of-bounds trick as `_gset`)."""
    if active is None:
        return arr.at[idx].add(val)
    oob = jnp.where(active, idx, arr.shape[0])
    return arr.at[oob].add(val, mode="drop")


def _gmin(active, arr, idx, val):
    """Gated `arr[idx] = min(arr[idx], val)` (same trick as `_gset`)."""
    if active is None:
        return arr.at[idx].min(val)
    oob = jnp.where(active, idx, arr.shape[0])
    return arr.at[oob].min(val, mode="drop")


def _next_completion(s: SimState):
    """(task, finish) of the earliest-finishing running task.

    Two-level search over the `fin_seg` invariant; the returned index is
    exactly `argmin(fin_run)` (first global minimum: the first segment
    holding the min value wins, then the first index inside it).
    """
    seg = jnp.argmin(s.fin_seg)
    blk = jax.lax.dynamic_slice(s.fin_run, (seg * SEG,), (SEG,))
    t = (seg * SEG + jnp.argmin(blk)).astype(jnp.int32)
    return t, s.fin_seg[seg]


def _push_ready_many(p: SimParams, wl: FlatWorkload, s: SimState,
                     tasks: jax.Array, bases: jax.Array,
                     do_push: jax.Array, rows_avail=None,
                     plan=None, kmode: str = "off") -> SimState:
    """FIFO-push up to K tasks (k ascending), caching their [P] rows.

    Replicates K sequential single-task pushes exactly. Slot assignment:
    with `b_k = ready_cnt + sum_{j<k} do_push_j`, push k lands iff
    `do_push_k & (b_k < R_MAX)` — before the queue saturates every
    accepted push *is* a do_push, so the do_push cumsum equals the
    accepted cumsum, and after saturation both reject everything.
    `rows_avail` lets a caller that knows the availability rows in closed
    form (arrival roots) skip the `_avail_rows` tensor.
    """
    t = jnp.maximum(tasks, 0)                             # [K]
    if rows_avail is None:
        rows_avail = _avail_rows(p, wl, s, t, bases, kmode)   # [K, P]
    rows_exec = p.exec_pe[wl.task_type[t]]                # [K, P]
    if plan is not None:
        # cluster slowdown stretches the cached exec rows at push time
        # (pe_slow is constant per scenario, so the cache stays valid;
        # x1.0 when healthy keeps the healthy plan bit-exact)
        rows_exec = rows_exec * s.pe_slow[None, :]
    want = do_push.astype(jnp.int32)
    before = s.ready_cnt + jnp.cumsum(want) - want        # [K] exclusive
    can = do_push & (before < R_MAX)
    acc = can.astype(jnp.int32)
    slots = s.ready_cnt + jnp.cumsum(acc) - acc           # [K]
    sl = jnp.where(can, slots, R_MAX)                     # drop rejected
    tix = jnp.where(do_push, t, s.status.shape[0])
    return s._replace(
        ready_ids=s.ready_ids.at[sl].set(t, mode="drop"),
        ready_avail=s.ready_avail.at[sl].set(rows_avail, mode="drop"),
        ready_exec=s.ready_exec.at[sl].set(rows_exec, mode="drop"),
        ready_cnt=s.ready_cnt + acc.sum(),
        ready_drop=s.ready_drop + (want - acc).sum(),
        status=s.status.at[tix].set(2, mode="drop"),
    )


def _pop_slot(s: SimState, slot: jax.Array, active=None) -> SimState:
    """Remove `slot` keeping FIFO order (left shift of the tail)."""
    ar = jnp.arange(R_MAX)
    tail = ar >= slot
    shifted = jnp.roll(s.ready_ids, -1)
    ready_ids = jnp.where(tail, shifted, s.ready_ids)
    ready_ids = ready_ids.at[R_MAX - 1].set(
        jnp.where(slot < R_MAX, -1, ready_ids[R_MAX - 1])
    )

    # cached rows shift with the ids; the duplicated last row is stale but
    # its ready_id is -1, so it is masked everywhere
    def shift_rows(a):
        return jnp.where(tail[:, None], jnp.roll(a, -1, axis=0), a)

    return s._replace(
        ready_ids=_gate(active, ready_ids, s.ready_ids),
        ready_avail=_gate(active, shift_rows(s.ready_avail), s.ready_avail),
        ready_exec=_gate(active, shift_rows(s.ready_exec), s.ready_exec),
        ready_cnt=s.ready_cnt - _gate_i(active))


def _assign(p: SimParams, wl: FlatWorkload, s: SimState, slot: jax.Array,
            pe: jax.Array, lat: jax.Array, sched_e: jax.Array,
            is_slow: jax.Array, feats: jax.Array,
            agree: jax.Array, active=None, plan=None) -> SimState:
    task = jnp.maximum(s.ready_ids[slot], 0)
    sched_done = jnp.maximum(s.sched_free, s.now) + lat
    avail = s.ready_avail[slot, pe]
    start = jnp.maximum(jnp.maximum(avail, s.pe_free[pe]),
                        jnp.maximum(sched_done, s.now))
    exec_t = s.ready_exec[slot, pe]
    finish = start + exec_t
    e_task = exec_t * p.pe_power[pe]
    act = _gate_i(active)
    d = s.d_ptr
    # accumulators: gate the summed result, not the addend — selecting the
    # addend to 0.0 blocks the mul+add FMA contraction the unmasked path
    # gets, and the two paths then drift by a ULP per decision
    s = s._replace(
        sched_free=_gate(active, sched_done, s.sched_free),
        status=_gset(active, s.status, task, 3),
        start=_gset(active, s.start, task, start),
        finish=_gset(active, s.finish, task, finish),
        fin_run=_gset(active, s.fin_run, task, finish),
        fin_seg=_gmin(active, s.fin_seg, task // SEG, finish),
        n_running=s.n_running + act,
        pe_of=_gset(active, s.pe_of, task, pe),
        pe_free=_gset(active, s.pe_free, pe, finish),
        pe_busy=_gadd(active, s.pe_busy, pe, exec_t),
        task_energy=_gate(active, s.task_energy + e_task, s.task_energy),
        sched_energy=_gate(active, s.sched_energy + sched_e, s.sched_energy),
        sched_time=_gate(active, s.sched_time + lat, s.sched_time),
        n_fast=s.n_fast + (1 - is_slow) * act,
        n_slow=s.n_slow + is_slow * act,
        n_sched=s.n_sched + act,
        d_ptr=d + act,
        log_feat=_gset(active, s.log_feat, d, feats),
        log_policy=_gset(active, s.log_policy, d, is_slow.astype(jnp.int8)),
        log_agree=_gset(active, s.log_agree, d, agree.astype(jnp.int8)),
        log_task=_gset(active, s.log_task, d, task),
    )
    if plan is not None:
        # a fault at tau revokes live assignments with assign_t < tau, so
        # a decision taken *at* a fault instant is never insta-killed
        s = s._replace(assign_t=_gset(active, s.assign_t, task, s.now))
    return _pop_slot(s, slot, active=active)


def _process_completion(p: SimParams, wl: FlatWorkload,
                        s: SimState, active=None, t=None,
                        plan=None, kmode: str = "off") -> SimState:
    if t is None:
        # earliest-finishing running task; when a completion is due, every
        # task at the minimum of `fin_run` has finish <= now, so this is
        # exactly argmin(where(status==3 & finish<=now, finish, inf))
        t, _ = _next_completion(s)
    act = _gate_i(active)
    s = s._replace(status=_gset(active, s.status, t, 4),
                   fin_run=_gset(active, s.fin_run, t, _INF),
                   n_running=s.n_running - act,
                   n_done=s.n_done + act)
    if plan is not None:
        tt = jnp.maximum(t, 0)
        rec = s.retries[tt] > 0
        if active is not None:
            rec &= active
        s = s._replace(
            inst_rem=_gadd(active, s.inst_rem, wl.inst_id[tt], -1),
            # a previously-killed task finishing anyway: recovery latency
            # is measured from its last kill to its final finish
            recovery_us=_gate(rec, s.recovery_us
                              + (s.finish[tt] - s.kill_t[tt]),
                              s.recovery_us),
            n_recovered=s.n_recovered + jnp.asarray(rec).astype(jnp.int32),
        )
    # restore the fin_seg invariant: rescan only the SEG-sized block of
    # the retired task (reads the post-scatter fin_run)
    seg = t // SEG
    blk = jax.lax.dynamic_slice(s.fin_run, (seg * SEG,), (SEG,))
    s = s._replace(fin_seg=_gset(active, s.fin_seg, seg, blk.min()))

    # all successors at once: they are distinct tasks, so the pred_rem
    # update and the pushes vectorize with no read-after-write hazard
    succ = wl.succs[t]                                    # [MS]
    valid = (jnp.arange(succ.shape[0]) < wl.n_succs[t]) & (succ >= 0)
    if active is not None:
        valid &= active
    sc = jnp.maximum(succ, 0)
    new_rem = s.pred_rem[sc] - 1
    scx = jnp.where(valid, sc, s.pred_rem.shape[0])
    s = s._replace(pred_rem=s.pred_rem.at[scx].set(new_rem, mode="drop"))
    ready_now = valid & (new_rem == 0)
    # availability (base) = max pred finish (all preds are done)
    pr = wl.preds[sc]                                     # [MS, MP]
    pv = jnp.arange(pr.shape[1])[None, :] < wl.n_preds[sc][:, None]
    bases = jnp.where(pv, s.finish[jnp.maximum(pr, 0)], _NEG).max(axis=1)
    return _push_ready_many(p, wl, s, sc, jnp.maximum(bases, s.now),
                            ready_now, plan=plan, kmode=kmode)


def _process_arrival(p: SimParams, wl: FlatWorkload, s: SimState,
                     active=None, plan=None) -> SimState:
    i = s.arr_ptr
    ic = jnp.minimum(i, wl.inst_arrival.shape[0] - 1)
    t_arr = wl.inst_arrival[ic]
    act = _gate_i(active)
    s = s._replace(
        arr_ptr=i + act,
        ring=_gset(active, s.ring, s.ring_ptr % RING, t_arr),
        ring_ptr=s.ring_ptr + act,
        arr_count=s.arr_count + act,
    )
    roots = wl.inst_roots[ic]                             # [MR]
    valid = (jnp.arange(roots.shape[0]) < wl.inst_n_roots[ic]) & (roots >= 0)
    if active is not None:
        valid &= active
    bases = jnp.full(roots.shape[0], t_arr)
    # roots have zero preds by construction, so their availability row is
    # exactly the arrival time on every PE (`_avail_rows` would reduce an
    # all -inf contrib tensor against `bases`)
    rows = jnp.broadcast_to(bases[:, None],
                            (roots.shape[0], s.pe_free.shape[0]))
    return _push_ready_many(p, wl, s, jnp.maximum(roots, 0), bases, valid,
                            rows_avail=rows, plan=plan)


# ---------------------------------------------------------------------------
# fault events (kill / deadline / drop) — only traced when a FaultPlan is
# threaded; `plan=None` callers never reach these.
# ---------------------------------------------------------------------------
def _pending_kill(plan, s: SimState):
    """(due, task, tau): earliest fault instant that revokes a live
    assignment — a running task whose PE has a permanent failure or
    transient glitch at tau with `assign_t < tau <= now`. Ties break to
    the lowest task id (argmin), matching `ref_sim`."""
    taus = flt.kill_times(plan)                         # [P, K]
    t_taus = taus[jnp.maximum(s.pe_of, 0)]              # [T, K]
    running = s.status == 3
    due = (running[:, None] & (s.assign_t[:, None] < t_taus)
           & (t_taus <= s.now))                         # [T, K]
    tau_t = jnp.where(due, t_taus, _INF).min(axis=1)    # [T]
    t = jnp.argmin(tau_t).astype(jnp.int32)
    return due.any(), t, tau_t[t]


def _drop_instance(p: SimParams, wl: FlatWorkload, s: SimState,
                   inst: jax.Array, active=None) -> SimState:
    """Cancel every unfinished task of instance `inst` (deadline miss or
    retry exhaustion). Running work rolls back its unexecuted tail
    (busy time + energy), queued tasks are purged from the FIFO with
    order preserved, and every victim retires as status 5 so the
    termination count (`n_done`) still converges."""
    T = s.status.shape[0]
    P = s.pe_free.shape[0]
    ar = jnp.arange(T)
    inst = jnp.maximum(inst, 0)
    victim = (wl.inst_id == inst) & wl.task_valid & (s.status < 4)
    if active is not None:
        victim &= active
    n_v = victim.sum().astype(jnp.int32)

    # roll back the unexecuted tail of running victims; keep the executed
    # prefix (that energy really was burned)
    runn = victim & (s.status == 3)
    pe = jnp.maximum(s.pe_of, 0)
    exec_total = jnp.where(runn, s.finish - s.start, 0.0)
    executed = jnp.where(runn, jnp.clip(s.now - s.start, 0.0, exec_total),
                         0.0)
    unexec = exec_total - executed
    pe_ix = jnp.where(runn, pe, P)
    pe_busy = s.pe_busy.at[pe_ix].add(-unexec, mode="drop")
    e_back = (jnp.where(runn, unexec * p.pe_power[pe], 0.0)).sum()
    # PEs that lost a victim rebuild pe_free from surviving assignments;
    # untouched PEs keep their exact value
    pe_hit = jnp.zeros(P, bool).at[pe_ix].set(True, mode="drop")
    surv = (s.status == 3) & ~victim
    surv_fin = jnp.full(P, _NEG).at[jnp.where(surv, pe, P)].max(
        s.finish, mode="drop")
    pe_free = jnp.where(pe_hit, jnp.maximum(surv_fin, s.now), s.pe_free)

    vix = jnp.where(victim, ar, T)
    status = s.status.at[vix].set(5, mode="drop")
    # -inf keeps dropped tasks out of the makespan / inst_fin maxima
    finish = s.finish.at[vix].set(_NEG, mode="drop")
    fin_run = s.fin_run.at[jnp.where(runn, ar, s.fin_run.shape[0])].set(
        _INF, mode="drop")
    # victims may span many segments: full fin_seg rebuild (exactly the
    # invariant value, so a no-op drop stays bit-identical)
    fin_seg = fin_run.reshape(-1, SEG).min(axis=1)

    # purge victims from the ready FIFO, preserving survivor order
    in_q = s.ready_ids >= 0
    is_v = jnp.where(in_q, victim[jnp.maximum(s.ready_ids, 0)], False)
    keep = in_q & ~is_v
    perm = jnp.argsort((~keep).astype(jnp.int32))  # stable: survivors first
    new_cnt = keep.sum().astype(jnp.int32)
    ids_p = jnp.where(jnp.arange(R_MAX) < new_cnt, s.ready_ids[perm], -1)

    return s._replace(
        status=status, finish=finish, fin_run=fin_run, fin_seg=fin_seg,
        start=s.start.at[vix].set(_INF, mode="drop"),
        assign_t=s.assign_t.at[vix].set(_INF, mode="drop"),
        pe_busy=pe_busy, pe_free=pe_free,
        task_energy=_gate(active, s.task_energy - e_back, s.task_energy),
        n_running=s.n_running - runn.sum().astype(jnp.int32),
        n_done=s.n_done + n_v,
        n_dropped_tasks=s.n_dropped_tasks + n_v,
        ready_ids=_gate(active, ids_p, s.ready_ids),
        ready_avail=_gate(active, s.ready_avail[perm], s.ready_avail),
        ready_exec=_gate(active, s.ready_exec[perm], s.ready_exec),
        ready_cnt=_gate(active, new_cnt, s.ready_cnt),
        inst_rem=_gset(active, s.inst_rem, inst, 0),
        job_dropped=_gset(active, s.job_dropped, inst, True),
    )


def _process_kill(plan, p: SimParams, wl: FlatWorkload, s: SimState,
                  t: jax.Array, active=None, kmode: str = "off") -> SimState:
    """Revoke the live assignment of running task `t` at the current time
    (`now` sits exactly on the fault instant: advance stops at every plan
    time). Executed work is wasted (`reexec_us`) but its energy/busy time
    stay; the unexecuted tail rolls back. Within the retry budget the task
    re-enters the FIFO tail at `now`; past it its whole job drops."""
    T = s.status.shape[0]
    t = jnp.maximum(t, 0)
    pe = jnp.maximum(s.pe_of[t], 0)
    exec_total = s.finish[t] - s.start[t]
    executed = jnp.clip(s.now - s.start[t], 0.0, exec_total)
    unexec = exec_total - executed
    act = _gate_i(active)
    exhausted = s.retries[t] >= plan.max_retries
    if active is None:
        rk = ~exhausted
        dr = exhausted
    else:
        rk = active & ~exhausted
        dr = active & exhausted

    others = (s.status == 3) & (s.pe_of == pe) & (jnp.arange(T) != t)
    new_free = jnp.maximum(jnp.where(others, s.finish, _NEG).max(), s.now)

    s = s._replace(
        status=_gset(active, s.status, t, 0),
        start=_gset(active, s.start, t, _INF),
        finish=_gset(active, s.finish, t, _INF),
        fin_run=_gset(active, s.fin_run, t, _INF),
        n_running=s.n_running - act,
        pe_of=_gset(active, s.pe_of, t, -1),
        assign_t=_gset(active, s.assign_t, t, _INF),
        pe_free=_gset(active, s.pe_free, pe, new_free),
        pe_busy=_gadd(active, s.pe_busy, pe, -unexec),
        task_energy=_gate(active, s.task_energy - unexec * p.pe_power[pe],
                          s.task_energy),
        retries=_gadd(active, s.retries, t, 1),
        kill_t=_gset(active, s.kill_t, t, s.now),
        n_kills=s.n_kills + act,
        n_retries=s.n_retries + jnp.asarray(rk).astype(jnp.int32),
        reexec_us=_gate(active, s.reexec_us + executed, s.reexec_us),
    )
    # restore the fin_seg invariant for the killed task's segment
    seg = t // SEG
    blk = jax.lax.dynamic_slice(s.fin_run, (seg * SEG,), (SEG,))
    s = s._replace(fin_seg=_gset(active, s.fin_seg, seg, blk.min()))

    # retry: back to the FIFO tail, availability re-based at now (preds
    # are all done, so the cached row is recomputable)
    s = _push_ready_many(p, wl, s, t[None], s.now[None],
                         jnp.asarray(rk)[None], plan=plan, kmode=kmode)
    # exhausted: the whole job goes
    return _drop_instance(p, wl, s, wl.inst_id[t], active=jnp.asarray(dr))


def _pending_deadline(plan, wl: FlatWorkload, s: SimState):
    """(due, inst): earliest arrived-but-incomplete instance past its
    deadline. Ties break to the lowest instance id."""
    I = wl.inst_arrival.shape[0]
    arrived = jnp.arange(I) < s.arr_ptr
    pend = arrived & wl.inst_valid & (s.inst_rem > 0)
    dl = jnp.where(pend, wl.inst_arrival + plan.deadline_us, _INF)
    due = pend & (dl <= s.now)
    inst = jnp.argmin(jnp.where(due, dl, _INF)).astype(jnp.int32)
    return due.any(), inst


def _next_wakeup(plan, wl: FlatWorkload, s: SimState,
                 fcaps=flt.FULL_CAPS) -> jax.Array:
    """Earliest strictly-future fault instant, repair, or pending job
    deadline — extra advance targets so `now` lands exactly on each fault
    event (a stop with nothing due simply advances again). Targets a
    capability rules out are statically dropped: a time that can never be
    strictly future (or never matters) contributes `inf` to the min, so
    skipping it is exact."""
    can_die, can_kill, has_deadline = fcaps
    parts = []
    if can_die:
        parts += [plan.pe_fail_at, plan.pe_repair_at]
    if can_kill:
        parts.append(plan.transient_at.reshape(-1))
    out = _INF
    if parts:
        times = jnp.concatenate(parts)
        out = jnp.where(times > s.now, times, _INF).min()
    if has_deadline:
        I = wl.inst_arrival.shape[0]
        arrived = jnp.arange(I) < s.arr_ptr
        pend = arrived & wl.inst_valid & (s.inst_rem > 0)
        dl = jnp.where(pend, wl.inst_arrival + plan.deadline_us, _INF)
        out = jnp.minimum(out, jnp.where(dl > s.now, dl, _INF).min())
    return jnp.asarray(out, jnp.float32)


# ---------------------------------------------------------------------------
# the main loop
# ---------------------------------------------------------------------------
def _init_state(wl: FlatWorkload, n_pes: int, pe_slow=None) -> SimState:
    T = wl.task_type.shape[0]
    I = wl.inst_arrival.shape[0]
    Tp = -(-T // SEG) * SEG       # fin_run padded so every segment is full
    inst_cnt = jnp.zeros(I, jnp.int32).at[
        jnp.where(wl.task_valid, wl.inst_id, I)
    ].add(1, mode="drop")
    return SimState(
        now=jnp.float32(0.0), stalled=jnp.array(False),
        sched_free=jnp.float32(0.0),
        arr_ptr=jnp.int32(0), n_done=jnp.int32(0), n_sched=jnp.int32(0),
        status=jnp.zeros(T, jnp.int8),
        pred_rem=wl.n_preds.astype(jnp.int32),
        start=jnp.full(T, _INF), finish=jnp.full(T, _INF),
        fin_run=jnp.full(Tp, _INF),
        fin_seg=jnp.full(Tp // SEG, _INF), n_running=jnp.int32(0),
        pe_of=jnp.full(T, -1, jnp.int32),
        pe_free=jnp.zeros(n_pes, jnp.float32),
        pe_busy=jnp.zeros(n_pes, jnp.float32),
        ready_ids=jnp.full(R_MAX, -1, jnp.int32),
        ready_cnt=jnp.int32(0), ready_drop=jnp.int32(0),
        ready_avail=jnp.zeros((R_MAX, n_pes), jnp.float32),
        ready_exec=jnp.zeros((R_MAX, n_pes), jnp.float32),
        task_energy=jnp.float32(0.0), sched_energy=jnp.float32(0.0),
        sched_time=jnp.float32(0.0),
        n_fast=jnp.int32(0), n_slow=jnp.int32(0),
        ring=jnp.zeros(RING, jnp.float32), ring_ptr=jnp.int32(0),
        arr_count=jnp.int32(0),
        d_ptr=jnp.int32(0),
        log_feat=jnp.zeros((T, N_FEATURES), jnp.float32),
        log_policy=jnp.zeros(T, jnp.int8),
        log_agree=jnp.zeros(T, jnp.int8),
        log_task=jnp.full(T, -1, jnp.int32),
        pe_alive=jnp.ones(n_pes, bool),
        pe_slow=(jnp.ones(n_pes, jnp.float32) if pe_slow is None
                 else jnp.asarray(pe_slow, jnp.float32)),
        assign_t=jnp.full(T, _INF),
        retries=jnp.zeros(T, jnp.int32),
        kill_t=jnp.zeros(T, jnp.float32),
        inst_rem=inst_cnt,
        job_dropped=jnp.zeros(I, bool),
        n_kills=jnp.int32(0), n_retries=jnp.int32(0),
        reexec_us=jnp.float32(0.0), n_dropped_tasks=jnp.int32(0),
        recovery_us=jnp.float32(0.0), n_recovered=jnp.int32(0),
    )


def _decide(mode: int, p: SimParams, wl: FlatWorkload, s: SimState,
            tree: DTree, rate_threshold: jax.Array,
            active=None, plan=None, kmode: str = "off") -> SimState:
    feats = _features(p, wl, s)
    n = s.ready_cnt.astype(jnp.float32)
    etf_lat = soc.etf_latency_us(n)
    etf_e = etf_lat * soc.SCHED_POWER_W

    def lut():
        if plan is None:
            return _lut_choice(p, wl, s)
        return _lut_choice_degraded(p, wl, s)[:2]

    def etf():
        if plan is None:
            return _etf_choice(p, wl, s, kmode)
        return _etf_choice_degraded(p, wl, s, kmode)[:2]

    if mode == MODE_LUT:
        slot, pe = lut()
        return _assign(p, wl, s, slot, pe, jnp.float32(soc.LUT_LATENCY_US),
                       jnp.float32(soc.LUT_ENERGY_UJ), jnp.int32(0), feats,
                       jnp.int32(0), active=active, plan=plan)
    if mode == MODE_ETF:
        slot, pe = etf()
        return _assign(p, wl, s, slot, pe, etf_lat, etf_e, jnp.int32(1),
                       feats, jnp.int32(0), active=active, plan=plan)
    if mode == MODE_ETF_IDEAL:
        slot, pe = etf()
        return _assign(p, wl, s, slot, pe, jnp.float32(0.0), jnp.float32(0.0),
                       jnp.int32(1), feats, jnp.int32(0), active=active,
                       plan=plan)
    if mode == MODE_ORACLE:
        # run both, follow the fast one, log whether they agree
        slot_f, pe_f = lut()
        slot_s, pe_s = etf()
        agree = ((s.ready_ids[slot_f] == s.ready_ids[slot_s])
                 & (pe_f == pe_s)).astype(jnp.int32)
        return _assign(p, wl, s, slot_f, pe_f,
                       jnp.float32(soc.LUT_LATENCY_US),
                       jnp.float32(soc.LUT_ENERGY_UJ), jnp.int32(0), feats,
                       agree, active=active, plan=plan)

    if mode == MODE_DAS:
        use_slow = tree.predict(feats).astype(bool)
        cls_e = jnp.float32(soc.DAS_CLS_ENERGY_UJ)
    elif mode == MODE_THRESHOLD:
        use_slow = feats[FEAT_RATE] >= rate_threshold
        cls_e = jnp.float32(0.0)
    else:  # pragma: no cover
        raise ValueError(f"unknown mode {mode}")

    slot_f, pe_f = lut()
    slot_s, pe_s = etf()
    slot = jnp.where(use_slow, slot_s, slot_f)
    pe = jnp.where(use_slow, pe_s, pe_f)
    lat = jnp.where(use_slow, etf_lat, jnp.float32(soc.LUT_LATENCY_US))
    e = jnp.where(use_slow, etf_e, jnp.float32(soc.LUT_ENERGY_UJ)) + cls_e
    return _assign(p, wl, s, slot, pe, lat, e, use_slow.astype(jnp.int32),
                   feats, jnp.int32(0), active=active, plan=plan)


def _masked_step(mode: int, params: SimParams, s: SimState,
                 wl: FlatWorkload, tree: DTree, rate_threshold: jax.Array,
                 plan, run: jax.Array, kmode: str = "off",
                 fcaps=flt.FULL_CAPS):
    """One super-step of gated phases (no `lax.switch`); returns (s, ev).

    Phases run in the sequential body's priority order (completion >
    arrival > decide > advance), but gates are *re-derived after each
    phase*, so one iteration retires several consecutive events whenever
    they would have fired back-to-back anyway — e.g. the last completion
    at a timestamp, then the arrival due at that timestamp, then the first
    scheduling decision. The retired event *sequence* is exactly the
    switch path's, hence every result field stays bit-identical; only the
    grouping into loop iterations changes, which `ev` (events retired this
    step, 0..4) accounts for so `n_iters` still equals the sequential
    count. `run=False` makes the whole step a no-op, which is how the
    batched driver freezes finished lanes. Used under vmap: a vmapped
    switch would execute all branches anyway and then select the *entire*
    carry once per branch, which dominated the sweep cost.
    """
    I = wl.inst_arrival.shape[0]
    can_die, can_kill, has_deadline = fcaps if plan is not None \
        else flt.NO_CAPS
    if plan is not None and can_die:
        s = s._replace(pe_alive=flt.alive_at(plan, s.now))
    # one two-level search serves completion detection, the completed task
    # index, AND the advance target (the switch path derives all three
    # from status/finish separately — same values, more passes)
    fin_idx, fin_val = _next_completion(s)
    c = run & (fin_val <= s.now)
    s = _process_completion(params, wl, s, active=c, t=fin_idx, plan=plan,
                            kmode=kmode)

    # a completion tie leaves another completion due: everything below
    # must wait for the next iteration then, exactly as the switch would
    next_fin = s.fin_seg.min()
    no_c = ~(next_fin <= s.now)

    # fault phases (priority: completion > kill > deadline > arrival).
    # Gates re-derive after each phase, mirroring the sequential 6-way
    # switch: a second due kill / deadline blocks everything later. A
    # phase the plan's static capabilities rule out (see
    # `faults.plan_capabilities`) is skipped at trace time — its `due`
    # predicate would be identically False, so the skip is exact, and
    # the per-trip cost of the kill/drop machinery (FIFO purges, fin_seg
    # rebuilds, re-push) vanishes for plans that can never fire it.
    k = dl = jnp.array(False)
    no_k = no_dl = jnp.array(True)
    if plan is not None and can_kill:
        k_due, k_task, _ = _pending_kill(plan, s)
        k = run & no_c & k_due
        s = _process_kill(plan, params, wl, s, k_task, active=k, kmode=kmode)
        no_k = ~_pending_kill(plan, s)[0]
    if plan is not None and has_deadline:
        dl_due, dl_inst = _pending_deadline(plan, wl, s)
        dl = run & no_c & no_k & dl_due
        s = _drop_instance(params, wl, s, dl_inst, active=dl)
        no_dl = ~_pending_deadline(plan, wl, s)[0]

    def arr_due(st):
        return (st.arr_ptr < wl.n_insts) & (
            wl.inst_arrival[jnp.minimum(st.arr_ptr, I - 1)] <= st.now
        )

    a = run & no_c & no_k & no_dl & arr_due(s)
    s = _process_arrival(params, wl, s, active=a, plan=plan)

    # same-timestamp arrivals: the next one blocks the decide phase; an
    # arrival can also arm an already-expired deadline (deadline_us ~ 0)
    no_a = ~arr_due(s)
    if plan is not None and has_deadline:
        no_dl = ~_pending_deadline(plan, wl, s)[0]
    can_decide = s.ready_cnt > 0
    if plan is not None and can_die:
        can_decide &= _can_schedule(mode, params, wl, s, tree,
                                    rate_threshold, kmode)
    d = run & no_c & no_k & no_dl & no_a & can_decide
    s = _decide(mode, params, wl, s, tree, rate_threshold, active=d,
                plan=plan, kmode=kmode)

    # advance when nothing else can fire *after* this trip's phases: a
    # decide leaves finish > now (exec times are positive), so no
    # completion becomes due mid-trip, but it can lower the next finish —
    # recompute the min. Queue emptiness is post-decide. After the final
    # completion the sequential cond exits without reaching do_advance,
    # hence the n_done guard.
    if plan is None or not (can_kill or has_deadline):
        # only a decide touched fin_seg this trip (no kills/drops traced)
        next_fin = jnp.where(d, s.fin_seg.min(), next_fin)
    else:
        # kills / drops also touched fin_seg — recompute unconditionally
        next_fin = s.fin_seg.min()
    if plan is not None and can_die:
        blocked = ~((s.ready_cnt > 0) & _can_schedule(
            mode, params, wl, s, tree, rate_threshold, kmode))
    else:
        blocked = s.ready_cnt == 0
    adv = (run & no_c & no_k & no_dl & no_a & blocked
           & (s.n_done < wl.n_tasks))
    next_arr = jnp.where(
        s.arr_ptr < wl.n_insts,
        wl.inst_arrival[jnp.minimum(s.arr_ptr, I - 1)], _INF,
    )
    nxt = jnp.minimum(next_fin, next_arr)
    if plan is not None and (can_die or can_kill or has_deadline):
        nxt = jnp.minimum(nxt, _next_wakeup(plan, wl, s, fcaps))
    stuck = ~jnp.isfinite(nxt)
    nxt = jnp.where(stuck, s.now, nxt)
    s = s._replace(
        now=jnp.where(adv, jnp.maximum(nxt, s.now), s.now),
        stalled=s.stalled | (adv & stuck),
    )
    ev = (c.astype(jnp.int32) + k.astype(jnp.int32) + dl.astype(jnp.int32)
          + a.astype(jnp.int32) + d.astype(jnp.int32)
          + adv.astype(jnp.int32))
    return s, ev


def _finalize(wl: FlatWorkload, s: SimState, iters: jax.Array,
              max_iters) -> SimResult:
    I = wl.inst_arrival.shape[0]
    # per-instance latency: segment-max of finish over each instance's tasks
    inst_fin = jnp.full(I, _NEG).at[wl.inst_id].max(
        jnp.where(wl.task_valid, s.finish, _NEG)
    )
    # dropped jobs are excluded from the latency mean (they have no
    # finish); without a FaultPlan `job_dropped` is all-False, so the mask
    # — and hence the mean — is unchanged bit-for-bit
    inst_exec = jnp.where(
        wl.inst_valid & ~s.job_dropped, inst_fin - wl.inst_arrival, jnp.nan
    )
    avg_exec = jnp.nanmean(inst_exec)
    makespan = jnp.where(wl.task_valid, s.finish, _NEG).max()
    total_e = s.task_energy + s.sched_energy
    return SimResult(
        avg_exec_us=avg_exec,
        makespan_us=makespan,
        total_energy_uj=total_e,
        task_energy_uj=s.task_energy,
        sched_energy_uj=s.sched_energy,
        sched_time_us=s.sched_time,
        edp=total_e * avg_exec,
        n_decisions=s.d_ptr,
        n_fast=s.n_fast,
        n_slow=s.n_slow,
        n_done=s.n_done,
        ready_drop=s.ready_drop,
        n_iters=iters,
        stalled=s.stalled,
        inst_exec_us=inst_exec,
        log_feat=s.log_feat,
        log_policy=s.log_policy,
        log_agree=s.log_agree,
        log_task=s.log_task,
        finish=s.finish,
        pe_of=s.pe_of,
        n_faults=s.n_kills,
        n_retries=s.n_retries,
        reexec_us=s.reexec_us,
        n_dropped_jobs=s.job_dropped.sum().astype(jnp.int32),
        n_dropped_tasks=s.n_dropped_tasks,
        recovery_us=s.recovery_us,
        n_recovered=s.n_recovered,
        job_dropped=s.job_dropped,
        # budget exhaustion: the loop stopped at its iteration cap (the
        # natural pathology backstop or an explicit `step_budget`) with
        # work remaining. `>=` because the batched engine's super-steps
        # retire several events per iteration and may overshoot the cap.
        stall_reason=jnp.where(
            s.stalled, jnp.int32(STALL_DEADLOCK),
            jnp.where((iters >= max_iters) & (s.n_done < wl.n_tasks),
                      jnp.int32(STALL_BUDGET), jnp.int32(STALL_NONE))),
    )


def _fault_iter_bound(base, T: int, I: int, n_pes: int, plan):
    """Iteration cap with fault headroom: every retry re-runs up to 4
    events for its task, each PE contributes at most its transient count
    plus fail/repair advance stops, and drops/deadlines retire at most one
    extra event per instance. Traced (depends on `plan.max_retries`)."""
    return (base + 4 * T * (plan.max_retries + 2)
            + n_pes * (flt.MAX_TRANSIENTS + 2) + 2 * I + 64)


def _simulate_impl(mode: int, params: SimParams, wl: FlatWorkload,
                   tree: DTree, rate_threshold: jax.Array,
                   plan=None, step_budget: int | None = None,
                   kernels: str = "off",
                   fcaps: tuple = flt.FULL_CAPS) -> SimResult:
    can_die, can_kill, has_deadline = fcaps if plan is not None \
        else flt.NO_CAPS
    T = wl.task_type.shape[0]
    I = wl.inst_arrival.shape[0]
    n_pes = params.pe_cluster.shape[0]
    max_iters = 3 * T + I + 64
    if plan is not None:
        max_iters = _fault_iter_bound(max_iters, T, I, n_pes, plan)
    if step_budget is not None:
        # device-side budget: a stuck chunk terminates on its own instead
        # of relying on a host watchdog; lanes that hit it report
        # STALL_BUDGET so the campaign layer can retry with a bigger cap
        max_iters = jnp.minimum(jnp.asarray(max_iters, jnp.int32),
                                jnp.int32(step_budget))

    def cond(carry):
        s, it = carry
        return (s.n_done < wl.n_tasks) & ~s.stalled & (it < max_iters)

    def body(carry):
        s, it = carry
        if plan is not None and can_die:
            s = s._replace(pe_alive=flt.alive_at(plan, s.now))
        completion_due = s.fin_seg.min() <= s.now
        arrival_due = (s.arr_ptr < wl.n_insts) & (
            wl.inst_arrival[jnp.minimum(s.arr_ptr, I - 1)] <= s.now
        )
        can_decide = s.ready_cnt > 0

        def do_completion(st):
            return _process_completion(params, wl, st, plan=plan,
                                       kmode=kernels)

        def do_arrival(st):
            return _process_arrival(params, wl, st, plan=plan)

        def do_decide(st):
            return _decide(mode, params, wl, st, tree, rate_threshold,
                           plan=plan, kmode=kernels)

        def do_advance(st):
            next_fin = st.fin_seg.min()
            next_arr = jnp.where(
                st.arr_ptr < wl.n_insts,
                wl.inst_arrival[jnp.minimum(st.arr_ptr, I - 1)], _INF,
            )
            nxt = jnp.minimum(next_fin, next_arr)
            if plan is not None and (can_die or can_kill or has_deadline):
                nxt = jnp.minimum(nxt, _next_wakeup(plan, wl, st, fcaps))
            # deadlock guard: nothing running and nothing left to arrive
            # means no event can ever become due again (unschedulable
            # tasks) — flag the stall so `cond` exits instead of spinning
            # here until `max_iters`.
            stuck = ~jnp.isfinite(nxt)
            nxt = jnp.where(stuck, st.now, nxt)
            return st._replace(now=jnp.maximum(nxt, st.now), stalled=stuck)

        if plan is None:
            branch = jnp.where(
                completion_due, 0,
                jnp.where(arrival_due, 1, jnp.where(can_decide, 2, 3)),
            )
            s = jax.lax.switch(
                branch, [do_completion, do_arrival, do_decide, do_advance],
                s,
            )
            return (s, it + 1)

        # fault path: six branches, priority completion > kill > deadline
        # > arrival > decide > advance; a decision additionally requires
        # the chosen scheduler to have a feasible (task, PE) pair.
        # Phases the plan's static capabilities rule out keep their
        # branch slot but with an identically-False gate and an identity
        # body — the per-iteration pending scans (and the heavy branch
        # bodies) are never traced, and the skip is exact because the
        # gate could never fire anyway (`faults.plan_capabilities`).
        if can_kill:
            k_due, k_task, _ = _pending_kill(plan, s)
        else:
            k_due, k_task = jnp.array(False), jnp.int32(0)
        if has_deadline:
            dl_due, dl_inst = _pending_deadline(plan, wl, s)
        else:
            dl_due, dl_inst = jnp.array(False), jnp.int32(0)
        if can_die:
            can_decide &= _can_schedule(mode, params, wl, s, tree,
                                        rate_threshold, kernels)

        def do_kill(st):
            if not can_kill:
                return st
            return _process_kill(plan, params, wl, st, k_task,
                                 kmode=kernels)

        def do_deadline(st):
            if not has_deadline:
                return st
            return _drop_instance(params, wl, st, dl_inst)

        branch = jnp.where(
            completion_due, 0,
            jnp.where(k_due, 1,
                      jnp.where(dl_due, 2,
                                jnp.where(arrival_due, 3,
                                          jnp.where(can_decide, 4, 5)))),
        )
        s = jax.lax.switch(
            branch,
            [do_completion, do_kill, do_deadline, do_arrival, do_decide,
             do_advance], s,
        )
        return (s, it + 1)

    pe_slow = None if plan is None \
        else flt.pe_slowdown(plan, params.pe_cluster)
    s0 = _init_state(wl, n_pes, pe_slow)
    s, iters = jax.lax.while_loop(cond, body, (s0, jnp.int32(0)))
    return _finalize(wl, s, iters, max_iters)


# `mode` is static (each mode compiles its own loop); everything else is
# traced. Returns a `SimResult` of scalars plus per-task/per-decision logs.
# The single-scenario path keeps the `lax.switch` body: unbatched, a switch
# runs only the taken branch, which beats the masked step's always-on phases.
# `plan=None` vs a `FaultPlan` changes the pytree structure, so each case
# compiles separately and the no-plan trace is untouched by the fault layer.
# `step_budget` is static: it reshapes the loop bound, not the data.
# `kernels` is the resolved `REPRO_SIM_KERNELS` dispatch mode (static: it
# picks which decision primitives get traced); callers resolve it from the
# env at call time so flipping the knob never hits a stale trace.
simulate = jax.jit(_simulate_impl, static_argnums=(0, 6, 7, 8))


# Trace counter for the batched engine, keyed for introspection: tests
# assert that a padded ragged sweep reuses ONE compiled executable instead
# of retracing for the short final chunk (the Python body below only runs
# when jit actually traces).
TRACE_COUNT = {"simulate_batch": 0}


class BatchTelemetry(NamedTuple):
    """Per-lane occupancy counters for one batched-engine call.

    Deliberately NOT part of `SimResult`: these depend on which scenarios
    share a chunk (the scalar-cond loop spins every lane until the whole
    chunk retires), so folding them into the result would break the
    bit-exactness contract between differently-chunked sweeps.
    """
    loop_trips: jax.Array    # [S] while-loop trips of the lane's shard
    active_trips: jax.Array  # [S] trips on which the lane was still live


def _simulate_batch_impl(mode, params, wls, tree, rate_threshold, plan,
                         tree_axis, thr_axis, plan_axis, step_budget=None,
                         kernels: str = "off", fcaps: tuple = flt.FULL_CAPS):
    TRACE_COUNT["simulate_batch"] += 1
    # One while loop over explicitly-batched state, vmapping only the
    # per-iteration step. Deliberately NOT `vmap(_simulate_impl)`: batching
    # a `while_loop` makes its cond per-lane, and the batching rule then
    # rewrites the body to `select(cond, body(carry), carry)` — a select
    # over the entire carry (including the [T, F] decision log) every
    # iteration. Here cond stays scalar (`any(running)`), finished lanes
    # are frozen by the step's `run` gate instead, and all per-lane writes
    # remain one-row scatters XLA applies in place.
    S, T = wls.task_type.shape
    I = wls.inst_arrival.shape[1]
    n_pes = params.pe_cluster.shape[0]
    max_iters = 3 * T + I + 64
    if plan is not None:
        # [S] when the plan is batched; `it < max_iters` is elementwise
        max_iters = _fault_iter_bound(max_iters, T, I, n_pes, plan)
    if step_budget is not None:
        max_iters = jnp.minimum(jnp.asarray(max_iters, jnp.int32),
                                jnp.int32(step_budget))

    step = jax.vmap(
        functools.partial(_masked_step, mode, params, kmode=kernels,
                          fcaps=fcaps),
        in_axes=(0, 0, tree_axis, thr_axis, plan_axis, 0),
    )

    def running(s, it):
        return (s.n_done < wls.n_tasks) & ~s.stalled & (it < max_iters)

    def cond(carry):
        s, it, act, trips = carry
        return jnp.any(running(s, it))

    def body(carry):
        s, it, act, trips = carry
        run = running(s, it)
        s, ev = step(s, wls, tree, rate_threshold, plan, run)
        # it counts retired *events*, matching the sequential n_iters
        # (a super-step can retire up to 4, or 6 with faults). A lane
        # within a few of max_iters may overshoot the cap by a couple of
        # events; max_iters is a pathology backstop, so the slack is
        # irrelevant in practice. `act`/`trips` are occupancy telemetry
        # only — they feed BatchTelemetry, never the result.
        return (s, it + ev, act + run.astype(jnp.int32), trips + 1)

    if plan is None:
        pe_slow, slow_axis = None, None
    else:
        pe_slow = plan.cluster_slowdown[..., params.pe_cluster]
        slow_axis = 0 if pe_slow.ndim == 2 else None
    s0 = jax.vmap(_init_state, in_axes=(0, None, slow_axis))(
        wls, n_pes, pe_slow)
    s, iters, act, trips = jax.lax.while_loop(
        cond, body,
        (s0, jnp.zeros(S, jnp.int32), jnp.zeros(S, jnp.int32),
         jnp.int32(0)))
    # max_iters is [S] when a batched plan varied it per lane, scalar
    # otherwise; either way every lane sees the same cap as the sequential
    # path, so `stall_reason` stays bit-exact between the two engines
    mi = jnp.asarray(max_iters, jnp.int32)
    mi_axis = 0 if mi.ndim == 1 else None
    res = jax.vmap(_finalize, in_axes=(0, 0, 0, mi_axis))(wls, s, iters, mi)
    # trips broadcasts to [S] so sharded runs report each lane against its
    # own shard's loop (sum over lanes == lane-iterations allocated)
    tel = BatchTelemetry(loop_trips=jnp.full((S,), trips, jnp.int32),
                         active_trips=act)
    return res, tel


_simulate_batch = jax.jit(_simulate_batch_impl,
                          static_argnums=(0, 6, 7, 8, 9, 10, 11))


def simulate_batch(mode: int, params: SimParams, wls: FlatWorkload,
                   tree: DTree, rate_threshold: jax.Array,
                   plan=None, step_budget: int | None = None,
                   kernels: str | None = None,
                   telemetry: list | None = None) -> SimResult:
    """`jax.vmap` of `simulate` over a leading scenario axis.

    `wls` is a stacked workload (`workloads.stack_workloads`): every field
    carries a leading `[S]` axis. `params` and `mode` are shared across
    scenarios. `tree` and `rate_threshold` are broadcast when unbatched, or
    swept per-scenario when given a leading `[S]` axis (threshold sweeps,
    per-scenario DAS trees). `plan` batches the same way: a single
    `faults.FaultPlan` is shared, `faults.stack_plans` sweeps one fault
    scenario per lane. Returns a `SimResult` whose every field has a
    leading `[S]` axis; scenario results are bit-identical to running
    `simulate` one scenario at a time on CPU — with or without faults.

    `kernels` overrides the `REPRO_SIM_KERNELS` knob (resolved here, at
    call time, so env flips dispatch correctly). When `telemetry` is a
    list, a per-call occupancy record (lane-iterations allocated vs.
    retired) is appended to it.
    """
    tree_axis = 0 if tree.feat.ndim == 2 else None
    thr_axis = 0 if getattr(rate_threshold, "ndim", 0) >= 1 else None
    plan_axis = 0 if plan is not None and plan.pe_fail_at.ndim == 2 else None
    fcaps = flt.plan_capabilities(plan) if plan is not None else flt.NO_CAPS
    res, tel = _simulate_batch(mode, params, wls, tree, rate_threshold,
                               plan, tree_axis, thr_axis, plan_axis,
                               step_budget, _kops.kernel_mode(kernels),
                               fcaps)
    if telemetry is not None:
        telemetry.append(_telemetry_record(res, tel))
    return res


def _telemetry_record(res: SimResult, tel: BatchTelemetry) -> dict:
    """Host-side occupancy record for one engine call (blocks on `tel`)."""
    loop = np.asarray(jax.device_get(tel.loop_trips))
    act = np.asarray(jax.device_get(tel.active_trips))
    events = np.asarray(jax.device_get(res.n_iters))
    allocated = int(loop.sum())
    return {
        "lanes": int(loop.shape[0]),
        "lane_trips": allocated,            # sum over lanes of shard trips
        "active_trips": int(act.sum()),     # trips with the lane still live
        "events": int(events.sum()),        # retired simulator events
        "occupancy": float(act.sum() / allocated) if allocated else 1.0,
    }


def to_device(wl: FlatWorkload) -> FlatWorkload:
    return FlatWorkload(*[jnp.asarray(x) for x in wl])


def result_at(res: SimResult, i: int) -> SimResult:
    """Slice scenario `i` out of a batched `SimResult`."""
    return jax.tree_util.tree_map(lambda x: x[i], res)


def _prep_plan(plan, params: SimParams, batched: bool):
    """Validate a user-supplied FaultPlan and move it to device arrays."""
    if plan is None:
        return None
    plan = flt.validate_plan(plan, n_pes=params.pe_cluster.shape[0],
                             n_clusters=params.cluster_pe_mask.shape[0])
    if not batched and flt.is_batched(plan):
        raise ValueError("run: got a batched FaultPlan (leading scenario "
                         "axis); use run_batch for plan sweeps")
    return flt.FaultPlan(*[jnp.asarray(x) for x in plan])


def _resolve_devices(devices) -> tuple:
    """Resolve the `devices=` knob (or `REPRO_BENCH_DEVICES`) to a device
    tuple. `None` -> env var if set, else every local device; an int takes
    the first k of `jax.devices()`; a sequence of devices passes through."""
    if devices is None:
        raw = os.environ.get("REPRO_BENCH_DEVICES")
        if raw is not None and raw.strip():
            try:
                devices = int(raw.strip())
            except ValueError:
                raise ValueError(
                    f"REPRO_BENCH_DEVICES={raw!r} is not an integer"
                ) from None
    if devices is None:
        return tuple(jax.devices())
    if isinstance(devices, int):
        avail = jax.devices()
        if not 1 <= devices <= len(avail):
            raise ValueError(
                f"devices={devices} out of range (1..{len(avail)} available)")
        return tuple(avail[:devices])
    return tuple(devices)


@functools.lru_cache(maxsize=None)
def _sharded_batch_fn(mode: int, tree_axis, thr_axis, plan_axis,
                      has_plan: bool, devices: tuple,
                      step_budget: int | None = None,
                      kernels: str = "off", fcaps: tuple = flt.FULL_CAPS):
    """Compiled scenario-sharded batch engine over a fixed device tuple.

    Shards the leading scenario axis of every batched argument across
    `devices` with `shard_map` (or a `jax.pmap` fallback). Each shard runs
    its own independent masked while loop — lanes never interact, so there
    is no collective in the body and no cross-device sync until the caller
    fetches: per-scenario results are bit-identical regardless of device
    count. Cached per (mode, batched-axes, devices) so every fixed-shape
    chunk of a sweep reuses one executable.
    """
    D = len(devices)

    def call(params, wls, tree, rate_threshold, plan):
        return _simulate_batch_impl(mode, params, wls, tree, rate_threshold,
                                    plan, tree_axis, thr_axis, plan_axis,
                                    step_budget, kernels, fcaps)

    if _shard_map is not None:
        mesh = Mesh(np.array(devices), ("s",))
        sh = PartitionSpec("s")
        rep = PartitionSpec()
        t_spec = sh if tree_axis == 0 else rep
        r_spec = sh if thr_axis == 0 else rep
        if has_plan:
            fn = _shard_map(call, mesh=mesh,
                            in_specs=(rep, sh, t_spec, r_spec,
                                      sh if plan_axis == 0 else rep),
                            out_specs=sh, check_rep=False)
            return jax.jit(fn)
        fn = _shard_map(
            lambda params, wls, tree, rt: call(params, wls, tree, rt, None),
            mesh=mesh, in_specs=(rep, sh, t_spec, r_spec), out_specs=sh,
            check_rep=False)
        return jax.jit(lambda params, wls, tree, rt, plan:
                       fn(params, wls, tree, rt))

    # pmap fallback: fold the device axis out of / back into the scenario
    # axis ([B] -> [D, B/D] -> engine -> [B]); in_axes mirror the specs
    pm = jax.pmap(call, devices=devices,
                  in_axes=(None, 0, tree_axis, thr_axis,
                           plan_axis if has_plan else None))

    def fold(x):
        return x.reshape((D, x.shape[0] // D) + x.shape[1:])

    def wrapped(params, wls, tree, rate_threshold, plan):
        wls = jax.tree_util.tree_map(fold, wls)
        if tree_axis == 0:
            tree = jax.tree_util.tree_map(fold, tree)
        if thr_axis == 0:
            rate_threshold = fold(rate_threshold)
        if has_plan and plan_axis == 0:
            plan = jax.tree_util.tree_map(fold, plan)
        out = pm(params, wls, tree, rate_threshold, plan)
        return jax.tree_util.tree_map(
            lambda x: x.reshape((-1,) + x.shape[2:]), out)

    return wrapped


def run(mode: int, wl: FlatWorkload, params: SimParams | None = None,
        tree: DTree | None = None,
        rate_threshold: float = 1e9,
        plan=None, step_budget: int | None = None,
        kernels: str | None = None) -> SimResult:
    """Convenience wrapper (host-side numpy workload ok). `plan` threads
    an optional `faults.FaultPlan` through the simulation; `step_budget`
    caps the event-loop iterations (stall diagnostics in
    `SimResult.stall_reason`); `kernels` overrides `REPRO_SIM_KERNELS`
    (decision-kernel dispatch, resolved at call time)."""
    params = params or make_params()
    tree = tree or always_fast_tree()
    plan = _prep_plan(plan, params, batched=False)
    fcaps = flt.plan_capabilities(plan) if plan is not None else flt.NO_CAPS
    return simulate(mode, params, to_device(wl), tree,
                    jnp.float32(rate_threshold), plan, step_budget,
                    _kops.kernel_mode(kernels), fcaps)


def run_batch(mode: int, wls, params: SimParams | None = None,
              tree: DTree | None = None,
              rate_threshold=1e9,
              batch_size: int | None = None,
              plan=None,
              devices=None,
              step_budget: int | None = None,
              kernels: str | None = None,
              telemetry: list | None = None) -> SimResult:
    """Sharded, streaming batched sweep over a scenario axis.

    `wls` is either a list of same-shape `FlatWorkload`s or an
    already-stacked workload (leading `[S]` axis on every field).
    `batch_size` chunks the scenario axis so peak memory stays bounded on
    large sweeps — benchmarks wire it to the `REPRO_BENCH_BATCH` env knob.
    `tree` / `rate_threshold` / `plan` (a `faults.FaultPlan`, batched via
    `faults.stack_plans`) may carry a leading `[S]` axis to vary per
    scenario; chunking slices them along with the workloads.

    Every chunk has the same fixed shape: the ragged final chunk is padded
    up to `batch_size` by replaying the last real scenario, and the pad
    lanes are sliced off before return — so a whole sweep (and every sweep
    of the same chunk size) reuses ONE compiled executable instead of
    retracing for the remainder chunk. `devices` (or `REPRO_BENCH_DEVICES`,
    default: all of `jax.devices()`) shards the scenario axis of each chunk
    across devices with `shard_map` (`jax.pmap` fallback); lanes are
    independent, so per-scenario results are bit-identical for any
    `batch_size` and any device count. Chunks are dispatched
    asynchronously and fetched once at the end, overlapping host-side tree
    slicing with device compute.

    `kernels` overrides the `REPRO_SIM_KERNELS` decision-kernel knob
    (resolved here at call time). When `telemetry` is a list, one
    occupancy record per chunk (lane-iterations allocated vs. retired) is
    appended to it — out-of-band so results stay bit-exact across chunk
    compositions.
    """
    from repro.core.workloads import stack_workloads

    if batch_size is not None and batch_size <= 0:
        raise ValueError(f"batch_size must be positive, got {batch_size}")
    params = params or make_params()
    tree = tree or always_fast_tree()
    plan = _prep_plan(plan, params, batched=True)
    if isinstance(wls, FlatWorkload):
        stacked = wls
    else:
        stacked = stack_workloads(wls)
    stacked = to_device(stacked)
    n = stacked.task_type.shape[0]
    plan_b = plan is not None and flt.is_batched(plan)
    if plan_b and plan.pe_fail_at.shape[0] != n:
        raise ValueError(
            f"run_batch: batched plan has {plan.pe_fail_at.shape[0]} "
            f"scenarios but the workload has {n}")
    if not isinstance(rate_threshold, jax.Array):
        rate_threshold = jnp.float32(rate_threshold)

    devs = _resolve_devices(devices)
    D = len(devs)
    kern = _kops.kernel_mode(kernels)
    fcaps = flt.plan_capabilities(plan) if plan is not None else flt.NO_CAPS
    # fixed chunk shape: user size clamped to n, rounded up to a device
    # multiple so every shard is equal-sized
    B = n if batch_size is None else min(batch_size, n)
    B = -(-B // D) * D
    if D == 1 and B >= n:
        # single device, single chunk: the plain vmapped engine
        return simulate_batch(mode, params, stacked, tree, rate_threshold,
                              plan, step_budget=step_budget, kernels=kern,
                              telemetry=telemetry)

    tree_b = tree.feat.ndim == 2
    thr_b = rate_threshold.ndim >= 1
    if D > 1:
        dispatch = _sharded_batch_fn(mode, 0 if tree_b else None,
                                     0 if thr_b else None,
                                     0 if plan_b else None,
                                     plan is not None, devs, step_budget,
                                     kern, fcaps)
    else:
        def dispatch(p, w, t, rt, pl):
            return _simulate_batch(mode, p, w, t, rt, pl,
                                   0 if tree_b else None,
                                   0 if thr_b else None,
                                   0 if plan_b else None, step_budget,
                                   kern, fcaps)

    n_pad = -(-n // B) * B
    # pad lanes replay the last real scenario; their results are dropped
    pad_idx = np.minimum(np.arange(n_pad), n - 1)
    chunks = []
    for lo in range(0, n_pad, B):
        ids = pad_idx[lo:lo + B]
        if ids[-1] == lo + B - 1:          # fully-real chunk: cheap slice
            def sl(x, lo=lo):
                return x[lo:lo + B]
        else:                              # final chunk: padded gather
            def sl(x, ids=ids):
                return x[ids]
        part = jax.tree_util.tree_map(sl, stacked)
        t = jax.tree_util.tree_map(sl, tree) if tree_b else tree
        rt = sl(rate_threshold) if thr_b else rate_threshold
        pl = jax.tree_util.tree_map(sl, plan) if plan_b else plan
        chunks.append(dispatch(params, part, t, rt, pl))
    # one blocking fetch for the whole sweep (dispatches above are async)
    chunks = jax.device_get(chunks)
    if telemetry is not None:
        for res_c, tel_c in chunks:
            telemetry.append(_telemetry_record(res_c, tel_c))
    return jax.tree_util.tree_map(
        lambda *xs: np.concatenate(xs, axis=0)[:n],
        *[res_c for res_c, _ in chunks])

"""Jittable discrete-event DSSoC simulator (DS3-style) in pure JAX.

One `lax.while_loop` iteration handles exactly one of, in priority order:
  1. a task completion whose finish time is due (finish <= now),
  2. a frame (application-instance) arrival that is due,
  3. one scheduling decision if the ready queue is non-empty,
  4. otherwise advance simulated time to the next event.

Scheduling overhead is modeled faithfully to the paper: the scheduler is a
serial resource (`sched_free`); each decision occupies it for the policy's
latency and burns the policy's energy; a scheduled task cannot start before
its decision completes.

Modes
-----
  MODE_LUT        fast scheduler only (paper's F)
  MODE_ETF        slow scheduler only (paper's S, Algorithm 1)
  MODE_ETF_IDEAL  ETF with zero scheduling overhead (paper's ETF-ideal)
  MODE_DAS        depth-2 decision tree preselects F or S per decision
  MODE_ORACLE     run both schedulers per decision, follow F, log agreement
                  (paper's "first execution" for oracle generation)
  MODE_THRESHOLD  static data-rate threshold picks F or S (paper's heuristic)

The whole simulation jits; `simulate` is wrapped in `jax.jit` with the mode
and capacity constants static.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import soc
from repro.core.workloads import FlatWorkload, FRAME_KBITS

MODE_LUT = 0
MODE_ETF = 1
MODE_ETF_IDEAL = 2
MODE_DAS = 3
MODE_ORACLE = 4
MODE_THRESHOLD = 5

MODE_NAMES = {
    MODE_LUT: "LUT",
    MODE_ETF: "ETF",
    MODE_ETF_IDEAL: "ETF-ideal",
    MODE_DAS: "DAS",
    MODE_ORACLE: "oracle",
    MODE_THRESHOLD: "threshold",
}

R_MAX = 256         # ready-queue capacity (compact buffer)
RING = 8            # data-rate shift register entries (paper: 8x16bit)
N_FEATURES = 62     # performance-counter feature bank size (paper Table I)
_INF = jnp.float32(jnp.inf)
_NEG = jnp.float32(-jnp.inf)


class SimParams(NamedTuple):
    """Device-side hardware tables (from `soc.SoCConfig`)."""

    exec_pe: jax.Array        # [n_types, P] f32 (inf = cannot run)
    pe_cluster: jax.Array     # [P] i32
    pe_power: jax.Array       # [P] f32
    lut_cluster: jax.Array    # [n_types] i32
    cluster_pe_mask: jax.Array  # [C, P] bool
    us_per_kb: jax.Array      # [] f32


def make_params(cfg: soc.SoCConfig | None = None) -> SimParams:
    cfg = cfg or soc.default_soc()
    return SimParams(
        exec_pe=jnp.asarray(cfg.exec_on_pe()),
        pe_cluster=jnp.asarray(cfg.pe_cluster),
        pe_power=jnp.asarray(cfg.cluster_power[cfg.pe_cluster]),
        lut_cluster=jnp.asarray(cfg.lut_cluster),
        cluster_pe_mask=jnp.asarray(cfg.cluster_pe_mask),
        us_per_kb=jnp.float32(cfg.us_per_kb),
    )


class DTree(NamedTuple):
    """Depth-2 decision tree over the feature vector (3 internal nodes).

    node 0 is the root; node 1 is the left child (feature < thr), node 2 the
    right child. Leaves: [LL, LR, RL, RR], value 1 => use the slow scheduler.
    """

    feat: jax.Array    # [3] i32 feature indices
    thr: jax.Array     # [3] f32 thresholds
    leaf: jax.Array    # [4] i32 in {0, 1}

    def predict(self, f: jax.Array) -> jax.Array:
        right0 = f[self.feat[0]] >= self.thr[0]
        node = jnp.where(right0, 2, 1)
        rightc = f[self.feat[node]] >= self.thr[node]
        idx = jnp.where(right0, 2, 0) + rightc.astype(jnp.int32)
        return self.leaf[idx]


def always_fast_tree() -> DTree:
    return DTree(feat=jnp.zeros(3, jnp.int32), thr=jnp.full(3, jnp.inf),
                 leaf=jnp.zeros(4, jnp.int32))


class SimState(NamedTuple):
    now: jax.Array          # [] f32
    sched_free: jax.Array   # [] f32 scheduler-core availability
    arr_ptr: jax.Array      # [] i32 next instance to arrive
    n_done: jax.Array       # [] i32
    n_sched: jax.Array      # [] i32 tasks scheduled so far
    status: jax.Array       # [T] i8 0=waiting 2=ready 3=running 4=done
    pred_rem: jax.Array     # [T] i32
    ready_base: jax.Array   # [T] f32 availability w/o comm
    start: jax.Array        # [T] f32
    finish: jax.Array       # [T] f32 (inf until scheduled)
    pe_of: jax.Array        # [T] i32 (-1 until scheduled)
    pe_free: jax.Array      # [P] f32
    pe_busy: jax.Array      # [P] f32 accumulated busy time
    ready_ids: jax.Array    # [R_MAX] i32 FIFO, -1 = empty
    ready_cnt: jax.Array    # [] i32
    ready_drop: jax.Array   # [] i32 overflow counter (should stay 0)
    task_energy: jax.Array  # [] f32 uJ
    sched_energy: jax.Array  # [] f32 uJ
    sched_time: jax.Array   # [] f32 us of scheduler occupancy
    n_fast: jax.Array       # [] i32
    n_slow: jax.Array       # [] i32
    ring: jax.Array         # [RING] f32 last arrival timestamps
    ring_ptr: jax.Array     # [] i32
    arr_count: jax.Array    # [] i32
    # decision logs (capacity T)
    d_ptr: jax.Array        # [] i32
    log_feat: jax.Array     # [T, N_FEATURES] f32
    log_policy: jax.Array   # [T] i8 (0 fast, 1 slow)
    log_agree: jax.Array    # [T] i8 (oracle: fast/slow decisions identical)
    log_task: jax.Array     # [T] i32


class SimResult(NamedTuple):
    avg_exec_us: jax.Array     # [] f32 mean instance latency
    makespan_us: jax.Array     # [] f32
    total_energy_uj: jax.Array  # [] f32 (task + scheduling energy)
    task_energy_uj: jax.Array
    sched_energy_uj: jax.Array
    sched_time_us: jax.Array
    edp: jax.Array             # [] f32 total energy * avg exec time
    n_decisions: jax.Array     # [] i32
    n_fast: jax.Array
    n_slow: jax.Array
    n_done: jax.Array
    ready_drop: jax.Array
    inst_exec_us: jax.Array    # [I] f32 per-instance latency (inf = invalid)
    # oracle / analysis logs
    log_feat: jax.Array
    log_policy: jax.Array
    log_agree: jax.Array
    log_task: jax.Array
    finish: jax.Array          # [T] f32
    pe_of: jax.Array           # [T] i32


# ---------------------------------------------------------------------------
# feature bank (paper Table I: task / PE / system counters, 62 total)
# ---------------------------------------------------------------------------
def _features(p: SimParams, wl: FlatWorkload, s: SimState) -> jax.Array:
    now = s.now
    cnt = jnp.minimum(s.arr_count, RING)
    oldest = jnp.where(
        s.arr_count >= RING, s.ring[s.ring_ptr % RING],
        s.ring[0],
    )
    newest = s.ring[(s.ring_ptr - 1) % RING]
    span = jnp.maximum(newest - oldest, 1e-3)
    rate_est = jnp.where(
        cnt >= 2,
        (cnt - 1).astype(jnp.float32) * FRAME_KBITS * 1000.0 / span,
        0.0,
    )  # Mbps

    pe_avail = jnp.maximum(s.pe_free - now, 0.0)              # [P]
    cl_avail = jnp.where(
        p.cluster_pe_mask, pe_avail[None, :], _INF
    ).min(axis=1)                                             # [C]
    util = s.pe_busy / jnp.maximum(now, 1e-3)                 # [P]

    head = s.ready_ids[0]
    head_ok = head >= 0
    h = jnp.maximum(head, 0)
    htype = wl.task_type[h]
    hpreds = wl.preds[h]                                      # [MP]
    hvalid = jnp.arange(hpreds.shape[0]) < wl.n_preds[h]
    pred_cl = jnp.where(
        hvalid & (hpreds >= 0),
        p.pe_cluster[jnp.maximum(s.pe_of[jnp.maximum(hpreds, 0)], 0)],
        -1,
    )
    pred_cl = jnp.pad(pred_cl, (0, max(0, 4 - pred_cl.shape[0])),
                      constant_values=-1)[:4]
    lut_cl = p.lut_cluster[htype]
    lut_pe = p.cluster_pe_mask[lut_cl].argmax()   # first PE of LUT cluster

    def z(x):
        return jnp.where(head_ok, x.astype(jnp.float32), 0.0)

    feats = jnp.concatenate([
        jnp.array([rate_est, s.ready_cnt.astype(jnp.float32)]),
        cl_avail,                                  # 6
        pe_avail,                                  # 19
        util,                                      # 19
        jnp.array([
            z(htype), z(wl.depth[h]), z(wl.app_id[h]), z(wl.out_kb[h]),
            z(p.exec_pe[htype, 0]),                        # exec on big
            z(p.exec_pe[htype, lut_pe]),                   # exec on LUT PE
            z(p.exec_pe[htype, lut_pe] * p.pe_power[lut_pe]),
            z(wl.n_preds[h]),
        ]),
        pred_cl.astype(jnp.float32),               # 4
        jnp.array([
            jnp.maximum(s.sched_free - now, 0.0),
            s.arr_count.astype(jnp.float32),
            s.n_done.astype(jnp.float32)
            / jnp.maximum(wl.n_tasks.astype(jnp.float32), 1.0),
            (s.status == 3).sum().astype(jnp.float32),
        ]),
    ])
    assert feats.shape == (N_FEATURES,), feats.shape
    return feats


FEAT_RATE = 0           # input data rate (paper's #1 feature)
FEAT_BIG_AVAIL = 2      # earliest availability of the big cluster (#2)
FEAT_NAMES = (
    ["input_data_rate", "ready_queue_len"]
    + [f"cluster_avail_{c}" for c in soc.CLUSTER_NAMES]
    + [f"pe_avail_{i}" for i in range(soc.N_PES)]
    + [f"pe_util_{i}" for i in range(soc.N_PES)]
    + ["head_type", "head_depth", "head_app", "head_out_kb",
       "head_exec_big", "head_exec_lut", "head_energy_lut", "head_n_preds"]
    + [f"head_pred_cluster_{k}" for k in range(4)]
    + ["sched_backlog", "arrivals_so_far", "done_frac", "running_count"]
)


# ---------------------------------------------------------------------------
# scheduler decision helpers
# ---------------------------------------------------------------------------
def _avail_with_comm(p: SimParams, wl: FlatWorkload, s: SimState,
                     tasks: jax.Array) -> jax.Array:
    """[R, P] task availability including NoC transfer from pred clusters."""
    t = jnp.maximum(tasks, 0)                       # [R]
    preds = wl.preds[t]                             # [R, MP]
    pv = (jnp.arange(preds.shape[1])[None, :] < wl.n_preds[t][:, None])
    pidx = jnp.maximum(preds, 0)
    pfin = jnp.where(pv, s.finish[pidx], _NEG)      # [R, MP]
    pkb = jnp.where(pv, wl.out_kb[pidx], 0.0)
    pcl = p.pe_cluster[jnp.maximum(s.pe_of[pidx], 0)]          # [R, MP]
    cross = pcl[:, :, None] != p.pe_cluster[None, None, :]     # [R, MP, P]
    contrib = jnp.where(
        pv[:, :, None],
        pfin[:, :, None] + pkb[:, :, None] * p.us_per_kb * cross,
        _NEG,
    )                                               # [R, MP, P]
    base = s.ready_base[t][:, None]                 # [R, 1]
    return jnp.maximum(contrib.max(axis=1), base)   # [R, P]


def _etf_choice(p: SimParams, wl: FlatWorkload, s: SimState):
    """Earliest-finish-time (task, pe) over the ready buffer (Algorithm 1)."""
    slot_ok = s.ready_ids >= 0                      # [R]
    tasks = s.ready_ids
    avail = _avail_with_comm(p, wl, s, tasks)       # [R, P]
    exec_t = p.exec_pe[wl.task_type[jnp.maximum(tasks, 0)]]    # [R, P]
    ft = jnp.maximum(jnp.maximum(avail, s.pe_free[None, :]), s.now) + exec_t
    ft = jnp.where(slot_ok[:, None], ft, _INF)
    flat = jnp.argmin(ft)
    slot = flat // ft.shape[1]
    pe = flat % ft.shape[1]
    return slot.astype(jnp.int32), pe.astype(jnp.int32)


def _lut_choice(p: SimParams, wl: FlatWorkload, s: SimState):
    """Fast scheduler: FIFO head -> most-energy-efficient cluster -> its
    earliest-free PE."""
    slot = jnp.int32(0)
    t = jnp.maximum(s.ready_ids[0], 0)
    cl = p.lut_cluster[wl.task_type[t]]
    free = jnp.where(p.cluster_pe_mask[cl], s.pe_free, _INF)
    pe = jnp.argmin(free).astype(jnp.int32)
    return slot, pe


# ---------------------------------------------------------------------------
# state mutations
# ---------------------------------------------------------------------------
def _push_ready(s: SimState, task: jax.Array, base: jax.Array,
                do_push: jax.Array) -> SimState:
    can = do_push & (s.ready_cnt < R_MAX)
    idx = jnp.clip(s.ready_cnt, 0, R_MAX - 1)
    ready_ids = jnp.where(
        can, s.ready_ids.at[idx].set(task), s.ready_ids
    )
    return s._replace(
        ready_ids=ready_ids,
        ready_cnt=s.ready_cnt + can.astype(jnp.int32),
        ready_drop=s.ready_drop + (do_push & ~can).astype(jnp.int32),
        status=jnp.where(do_push, s.status.at[task].set(2), s.status),
        ready_base=jnp.where(
            do_push, s.ready_base.at[task].set(base), s.ready_base
        ),
    )


def _pop_slot(s: SimState, slot: jax.Array) -> SimState:
    """Remove `slot` keeping FIFO order (left shift of the tail)."""
    ar = jnp.arange(R_MAX)
    shifted = jnp.roll(s.ready_ids, -1)
    ready_ids = jnp.where(ar >= slot, shifted, s.ready_ids)
    ready_ids = ready_ids.at[R_MAX - 1].set(
        jnp.where(slot < R_MAX, -1, ready_ids[R_MAX - 1])
    )
    return s._replace(ready_ids=ready_ids, ready_cnt=s.ready_cnt - 1)


def _assign(p: SimParams, wl: FlatWorkload, s: SimState, slot: jax.Array,
            pe: jax.Array, lat: jax.Array, sched_e: jax.Array,
            is_slow: jax.Array, feats: jax.Array,
            agree: jax.Array) -> SimState:
    task = jnp.maximum(s.ready_ids[slot], 0)
    sched_done = jnp.maximum(s.sched_free, s.now) + lat
    avail = _avail_with_comm(p, wl, s, s.ready_ids)[slot, pe]
    start = jnp.maximum(jnp.maximum(avail, s.pe_free[pe]),
                        jnp.maximum(sched_done, s.now))
    exec_t = p.exec_pe[wl.task_type[task], pe]
    finish = start + exec_t
    e_task = exec_t * p.pe_power[pe]
    d = s.d_ptr
    s = s._replace(
        sched_free=sched_done,
        status=s.status.at[task].set(3),
        start=s.start.at[task].set(start),
        finish=s.finish.at[task].set(finish),
        pe_of=s.pe_of.at[task].set(pe),
        pe_free=s.pe_free.at[pe].set(finish),
        pe_busy=s.pe_busy.at[pe].add(exec_t),
        task_energy=s.task_energy + e_task,
        sched_energy=s.sched_energy + sched_e,
        sched_time=s.sched_time + lat,
        n_fast=s.n_fast + (1 - is_slow),
        n_slow=s.n_slow + is_slow,
        n_sched=s.n_sched + 1,
        d_ptr=d + 1,
        log_feat=s.log_feat.at[d].set(feats),
        log_policy=s.log_policy.at[d].set(is_slow.astype(jnp.int8)),
        log_agree=s.log_agree.at[d].set(agree.astype(jnp.int8)),
        log_task=s.log_task.at[d].set(task),
    )
    return _pop_slot(s, slot)


def _process_completion(p: SimParams, wl: FlatWorkload,
                        s: SimState) -> SimState:
    due = (s.status == 3) & (s.finish <= s.now)
    t = jnp.argmin(jnp.where(due, s.finish, _INF)).astype(jnp.int32)
    s = s._replace(status=s.status.at[t].set(4), n_done=s.n_done + 1)

    def body(k, st):
        succ = wl.succs[t, k]
        valid = (k < wl.n_succs[t]) & (succ >= 0)
        sc = jnp.maximum(succ, 0)
        new_rem = st.pred_rem[sc] - 1
        pred_rem = jnp.where(
            valid, st.pred_rem.at[sc].set(new_rem), st.pred_rem
        )
        st = st._replace(pred_rem=pred_rem)
        ready_now = valid & (new_rem == 0)
        # availability (base) = max pred finish (all preds are done)
        pr = wl.preds[sc]
        pv = jnp.arange(pr.shape[0]) < wl.n_preds[sc]
        base = jnp.where(pv, st.finish[jnp.maximum(pr, 0)], _NEG).max()
        return _push_ready(st, sc, jnp.maximum(base, st.now), ready_now)

    return jax.lax.fori_loop(0, wl.succs.shape[1], body, s)


def _process_arrival(wl: FlatWorkload, s: SimState) -> SimState:
    i = s.arr_ptr
    t_arr = wl.inst_arrival[i]
    s = s._replace(
        arr_ptr=i + 1,
        ring=s.ring.at[s.ring_ptr % RING].set(t_arr),
        ring_ptr=s.ring_ptr + 1,
        arr_count=s.arr_count + 1,
    )

    def body(k, st):
        r = wl.inst_roots[i, k]
        valid = (k < wl.inst_n_roots[i]) & (r >= 0)
        return _push_ready(st, jnp.maximum(r, 0), t_arr, valid)

    return jax.lax.fori_loop(0, wl.inst_roots.shape[1], body, s)


# ---------------------------------------------------------------------------
# the main loop
# ---------------------------------------------------------------------------
def _init_state(wl: FlatWorkload, n_pes: int) -> SimState:
    T = wl.task_type.shape[0]
    return SimState(
        now=jnp.float32(0.0), sched_free=jnp.float32(0.0),
        arr_ptr=jnp.int32(0), n_done=jnp.int32(0), n_sched=jnp.int32(0),
        status=jnp.zeros(T, jnp.int8),
        pred_rem=wl.n_preds.astype(jnp.int32),
        ready_base=jnp.zeros(T, jnp.float32),
        start=jnp.full(T, _INF), finish=jnp.full(T, _INF),
        pe_of=jnp.full(T, -1, jnp.int32),
        pe_free=jnp.zeros(n_pes, jnp.float32),
        pe_busy=jnp.zeros(n_pes, jnp.float32),
        ready_ids=jnp.full(R_MAX, -1, jnp.int32),
        ready_cnt=jnp.int32(0), ready_drop=jnp.int32(0),
        task_energy=jnp.float32(0.0), sched_energy=jnp.float32(0.0),
        sched_time=jnp.float32(0.0),
        n_fast=jnp.int32(0), n_slow=jnp.int32(0),
        ring=jnp.zeros(RING, jnp.float32), ring_ptr=jnp.int32(0),
        arr_count=jnp.int32(0),
        d_ptr=jnp.int32(0),
        log_feat=jnp.zeros((T, N_FEATURES), jnp.float32),
        log_policy=jnp.zeros(T, jnp.int8),
        log_agree=jnp.zeros(T, jnp.int8),
        log_task=jnp.full(T, -1, jnp.int32),
    )


def _decide(mode: int, p: SimParams, wl: FlatWorkload, s: SimState,
            tree: DTree, rate_threshold: jax.Array) -> SimState:
    feats = _features(p, wl, s)
    n = s.ready_cnt.astype(jnp.float32)
    etf_lat = soc.etf_latency_us(n)
    etf_e = etf_lat * soc.SCHED_POWER_W

    if mode == MODE_LUT:
        slot, pe = _lut_choice(p, wl, s)
        return _assign(p, wl, s, slot, pe, jnp.float32(soc.LUT_LATENCY_US),
                       jnp.float32(soc.LUT_ENERGY_UJ), jnp.int32(0), feats,
                       jnp.int32(0))
    if mode == MODE_ETF:
        slot, pe = _etf_choice(p, wl, s)
        return _assign(p, wl, s, slot, pe, etf_lat, etf_e, jnp.int32(1),
                       feats, jnp.int32(0))
    if mode == MODE_ETF_IDEAL:
        slot, pe = _etf_choice(p, wl, s)
        return _assign(p, wl, s, slot, pe, jnp.float32(0.0), jnp.float32(0.0),
                       jnp.int32(1), feats, jnp.int32(0))
    if mode == MODE_ORACLE:
        # run both, follow the fast one, log whether they agree
        slot_f, pe_f = _lut_choice(p, wl, s)
        slot_s, pe_s = _etf_choice(p, wl, s)
        agree = ((s.ready_ids[slot_f] == s.ready_ids[slot_s])
                 & (pe_f == pe_s)).astype(jnp.int32)
        return _assign(p, wl, s, slot_f, pe_f,
                       jnp.float32(soc.LUT_LATENCY_US),
                       jnp.float32(soc.LUT_ENERGY_UJ), jnp.int32(0), feats,
                       agree)

    if mode == MODE_DAS:
        use_slow = tree.predict(feats).astype(bool)
        cls_e = jnp.float32(soc.DAS_CLS_ENERGY_UJ)
    elif mode == MODE_THRESHOLD:
        use_slow = feats[FEAT_RATE] >= rate_threshold
        cls_e = jnp.float32(0.0)
    else:  # pragma: no cover
        raise ValueError(f"unknown mode {mode}")

    slot_f, pe_f = _lut_choice(p, wl, s)
    slot_s, pe_s = _etf_choice(p, wl, s)
    slot = jnp.where(use_slow, slot_s, slot_f)
    pe = jnp.where(use_slow, pe_s, pe_f)
    lat = jnp.where(use_slow, etf_lat, jnp.float32(soc.LUT_LATENCY_US))
    e = jnp.where(use_slow, etf_e, jnp.float32(soc.LUT_ENERGY_UJ)) + cls_e
    return _assign(p, wl, s, slot, pe, lat, e, use_slow.astype(jnp.int32),
                   feats, jnp.int32(0))


@functools.partial(jax.jit, static_argnums=(0,))
def simulate(mode: int, params: SimParams, wl: FlatWorkload,
             tree: DTree, rate_threshold: jax.Array) -> SimResult:
    T = wl.task_type.shape[0]
    I = wl.inst_arrival.shape[0]
    n_pes = params.pe_cluster.shape[0]
    max_iters = 3 * T + I + 64

    def cond(carry):
        s, it = carry
        return (s.n_done < wl.n_tasks) & (it < max_iters)

    def body(carry):
        s, it = carry
        completion_due = jnp.any((s.status == 3) & (s.finish <= s.now))
        arrival_due = (s.arr_ptr < wl.n_insts) & (
            wl.inst_arrival[jnp.minimum(s.arr_ptr, I - 1)] <= s.now
        )
        can_decide = s.ready_cnt > 0

        def do_completion(st):
            return _process_completion(params, wl, st)

        def do_arrival(st):
            return _process_arrival(wl, st)

        def do_decide(st):
            return _decide(mode, params, wl, st, tree, rate_threshold)

        def do_advance(st):
            next_fin = jnp.where(st.status == 3, st.finish, _INF).min()
            next_arr = jnp.where(
                st.arr_ptr < wl.n_insts,
                wl.inst_arrival[jnp.minimum(st.arr_ptr, I - 1)], _INF,
            )
            nxt = jnp.minimum(next_fin, next_arr)
            # deadlock guard: if nothing is pending, jump past the horizon
            nxt = jnp.where(jnp.isfinite(nxt), nxt, st.now)
            return st._replace(now=jnp.maximum(nxt, st.now))

        branch = jnp.where(
            completion_due, 0,
            jnp.where(arrival_due, 1, jnp.where(can_decide, 2, 3)),
        )
        s = jax.lax.switch(
            branch, [do_completion, do_arrival, do_decide, do_advance], s
        )
        return (s, it + 1)

    s0 = _init_state(wl, n_pes)
    s, iters = jax.lax.while_loop(cond, body, (s0, jnp.int32(0)))

    # per-instance latency: segment-max of finish over each instance's tasks
    inst_fin = jnp.full(I, _NEG).at[wl.inst_id].max(
        jnp.where(wl.task_valid, s.finish, _NEG)
    )
    inst_exec = jnp.where(
        wl.inst_valid, inst_fin - wl.inst_arrival, jnp.nan
    )
    avg_exec = jnp.nanmean(inst_exec)
    makespan = jnp.where(wl.task_valid, s.finish, _NEG).max()
    total_e = s.task_energy + s.sched_energy
    return SimResult(
        avg_exec_us=avg_exec,
        makespan_us=makespan,
        total_energy_uj=total_e,
        task_energy_uj=s.task_energy,
        sched_energy_uj=s.sched_energy,
        sched_time_us=s.sched_time,
        edp=total_e * avg_exec,
        n_decisions=s.d_ptr,
        n_fast=s.n_fast,
        n_slow=s.n_slow,
        n_done=s.n_done,
        ready_drop=s.ready_drop,
        inst_exec_us=inst_exec,
        log_feat=s.log_feat,
        log_policy=s.log_policy,
        log_agree=s.log_agree,
        log_task=s.log_task,
        finish=s.finish,
        pe_of=s.pe_of,
    )


def to_device(wl: FlatWorkload) -> FlatWorkload:
    return FlatWorkload(*[jnp.asarray(x) for x in wl])


def run(mode: int, wl: FlatWorkload, params: SimParams | None = None,
        tree: DTree | None = None,
        rate_threshold: float = 1e9) -> SimResult:
    """Convenience wrapper (host-side numpy workload ok)."""
    params = params or make_params()
    tree = tree or always_fast_tree()
    return simulate(mode, params, to_device(wl), tree,
                    jnp.float32(rate_threshold))

"""Classifier zoo for the DAS preselection step.

Implemented from scratch (no sklearn available offline):
  * Decision trees (exhaustive threshold search, Gini impurity) for depths
    1..16 — the paper adopts depth 2 on 2 features.
  * Logistic regression trained with full-batch gradient descent in JAX —
    the paper's LR baseline (Table II).
  * Mutual-information-style univariate feature scoring for the feature
    space exploration (Section IV-B).

Storage accounting follows the paper's methodology: a DT node stores a
feature id + threshold (or a leaf label); LR stores one weight per feature
plus a bias.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import DTree


# ---------------------------------------------------------------------------
# Decision tree (CART, Gini)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: "_Node | None" = None
    right: "_Node | None" = None
    label: int = 0
    is_leaf: bool = False


def _gini_split(x: np.ndarray, y: np.ndarray, w: np.ndarray,
                n_thresholds: int = 64):
    """Best (threshold, gini) for one feature column with sample weights.
    Candidate thresholds are quantiles — exhaustive over up to n_thresholds
    candidate cuts."""
    qs = np.unique(np.quantile(x, np.linspace(0.02, 0.98, n_thresholds)))
    if qs.size == 0:
        return None
    best = None
    wtot = w.sum()
    for thr in qs:
        right = x >= thr
        wr = w[right].sum()
        wl = wtot - wr
        if wl <= 0 or wr <= 0:
            continue
        pl = (w[~right] * y[~right]).sum() / wl
        pr = (w[right] * y[right]).sum() / wr
        g = (wl / wtot) * 2 * pl * (1 - pl) + (wr / wtot) * 2 * pr * (1 - pr)
        if best is None or g < best[1]:
            best = (float(thr), float(g))
    return best


def _wlabel(y: np.ndarray, w: np.ndarray) -> int:
    if y.size == 0:
        return 0
    p = (w * y).sum() / w.sum()
    return int(p >= 0.5)


def _build(x: np.ndarray, y: np.ndarray, w: np.ndarray, depth: int,
           min_samples: int = 8) -> _Node:
    node = _Node()
    if depth == 0 or y.size < min_samples or y.min() == y.max():
        node.is_leaf = True
        node.label = _wlabel(y, w)
        return node
    best = None  # (gini, feat, thr)
    for f in range(x.shape[1]):
        r = _gini_split(x[:, f], y, w)
        if r is not None and (best is None or r[1] < best[0]):
            best = (r[1], f, r[0])
    if best is None:
        node.is_leaf = True
        node.label = _wlabel(y, w)
        return node
    _, f, thr = best
    right = x[:, f] >= thr
    if right.all() or (~right).all():
        node.is_leaf = True
        node.label = _wlabel(y, w)
        return node
    node.feature, node.threshold = f, thr
    node.left = _build(x[~right], y[~right], w[~right], depth - 1,
                       min_samples)
    node.right = _build(x[right], y[right], w[right], depth - 1, min_samples)
    return node


@dataclasses.dataclass
class DecisionTree:
    root: _Node
    depth: int
    feature_ids: List[int]   # column ids used at fit time (global feature ids)

    @staticmethod
    def fit(x: np.ndarray, y: np.ndarray, depth: int,
            feature_ids: Sequence[int] | None = None,
            class_weight: str | None = "balanced") -> "DecisionTree":
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.int32)
        if feature_ids is None:
            feature_ids = list(range(x.shape[1]))
        if class_weight == "balanced" and 0 < y.sum() < y.size:
            w1 = y.size / (2.0 * y.sum())
            w0 = y.size / (2.0 * (y.size - y.sum()))
            w = np.where(y == 1, w1, w0).astype(np.float64)
        else:
            w = np.ones(y.size, np.float64)
        return DecisionTree(
            root=_build(x, y, w, depth), depth=depth,
            feature_ids=list(feature_ids),
        )

    def predict(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float64)
        out = np.zeros(x.shape[0], np.int32)

        def walk(node: _Node, idx: np.ndarray):
            if node.is_leaf:
                out[idx] = node.label
                return
            right = x[idx, node.feature] >= node.threshold
            walk(node.left, idx[~right])
            walk(node.right, idx[right])

        walk(self.root, np.arange(x.shape[0]))
        return out

    def accuracy(self, x: np.ndarray, y: np.ndarray) -> float:
        return float((self.predict(x) == np.asarray(y)).mean())

    def n_nodes(self) -> int:
        def cnt(n: _Node) -> int:
            return 1 if n.is_leaf else 1 + cnt(n.left) + cnt(n.right)
        return cnt(self.root)

    def storage_kb(self) -> float:
        """Paper-style storage: internal nodes keep (feat id u8, thr f32),
        leaves keep a 1-byte label."""
        def walk(n: _Node):
            return (1,) if n.is_leaf else (5 + walk(n.left)[0]
                                           + walk(n.right)[0],)
        return walk(self.root)[0] / 1024.0

    def to_depth2_arrays(self) -> DTree:
        """Lower a depth<=2 tree to the simulator's fixed DTree arrays.

        Missing children become pass-through nodes replicating the parent's
        leaf label.
        """
        feat = np.zeros(3, np.int32)
        thr = np.zeros(3, np.float32)
        leaf = np.zeros(4, np.int32)
        r = self.root
        if r.is_leaf:
            feat[:] = 0
            thr[:] = np.inf  # everything goes left
            leaf[:] = r.label
            return DTree(jnp.asarray(feat), jnp.asarray(thr),
                         jnp.asarray(leaf))
        feat[0] = self.feature_ids[r.feature]
        thr[0] = r.threshold
        for side, child in ((0, r.left), (1, r.right)):
            node_i = 1 + side
            if child.is_leaf:
                feat[node_i] = 0
                thr[node_i] = np.inf
                leaf[2 * side] = child.label
                leaf[2 * side + 1] = child.label
            else:
                feat[node_i] = self.feature_ids[child.feature]
                thr[node_i] = child.threshold
                leaf[2 * side] = child.left.label
                leaf[2 * side + 1] = child.right.label
        return DTree(jnp.asarray(feat), jnp.asarray(thr), jnp.asarray(leaf))


# ---------------------------------------------------------------------------
# Logistic regression (JAX, full-batch GD with feature standardization)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LogisticRegression:
    w: np.ndarray
    b: float
    mu: np.ndarray
    sigma: np.ndarray

    @staticmethod
    def fit(x: np.ndarray, y: np.ndarray, steps: int = 400,
            lr: float = 0.3, l2: float = 1e-4) -> "LogisticRegression":
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        mu = x.mean(0)
        sigma = x.std(0) + 1e-6
        xs = jnp.asarray((x - mu) / sigma)
        yj = jnp.asarray(y)

        def loss(params):
            w, b = params
            logits = xs @ w + b
            nll = jnp.mean(
                jnp.maximum(logits, 0) - logits * yj
                + jnp.log1p(jnp.exp(-jnp.abs(logits)))
            )
            return nll + l2 * jnp.sum(w * w)

        grad = jax.jit(jax.grad(loss))
        w = jnp.zeros(x.shape[1])
        b = jnp.float32(0.0)
        for _ in range(steps):
            gw, gb = grad((w, b))
            w = w - lr * gw
            b = b - lr * gb
        return LogisticRegression(np.asarray(w), float(b), mu, sigma)

    def predict(self, x: np.ndarray) -> np.ndarray:
        xs = (np.asarray(x, np.float32) - self.mu) / self.sigma
        return (xs @ self.w + self.b >= 0).astype(np.int32)

    def accuracy(self, x: np.ndarray, y: np.ndarray) -> float:
        return float((self.predict(x) == np.asarray(y)).mean())

    def storage_kb(self) -> float:
        # one f32 weight per feature + bias
        return (self.w.size + 1) * 4 / 1024.0


# ---------------------------------------------------------------------------
# Feature scoring / selection
# ---------------------------------------------------------------------------
def feature_scores(x: np.ndarray, y: np.ndarray, depth: int = 2) -> np.ndarray:
    """Univariate score per feature = accuracy of a depth-`depth` stump tree
    trained on that feature alone (the paper's 'feature importance')."""
    scores = np.zeros(x.shape[1])
    for f in range(x.shape[1]):
        t = DecisionTree.fit(x[:, [f]], y, depth=depth, feature_ids=[f])
        scores[f] = t.accuracy(x[:, [f]], y)
    return scores


def greedy_select(x: np.ndarray, y: np.ndarray, k: int,
                  depth: int = 2) -> List[int]:
    """Greedy forward feature selection maximizing DT accuracy."""
    chosen: List[int] = []
    for _ in range(k):
        best = None
        for f in range(x.shape[1]):
            if f in chosen:
                continue
            cols = chosen + [f]
            t = DecisionTree.fit(x[:, cols], y, depth=depth, feature_ids=cols)
            acc = t.accuracy(x[:, cols], y)
            if best is None or acc > best[1]:
                best = (f, acc)
        chosen.append(best[0])
    return chosen

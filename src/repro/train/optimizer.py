"""AdamW with global-norm clipping and warmup+cosine schedule (pure JAX,
no optax). Optimizer state shards exactly like the parameters (ZeRO-style
via GSPMD)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    # fp32 master copy when training with bf16 weights (mixed precision:
    # every param collective then moves 2-byte tensors; masters live only
    # in the sharded optimizer state). None => params are the masters.
    master: Any = None


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr_peak * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (
        1.0 + jnp.cos(np.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params, keep_master: bool = False) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if keep_master else None)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros), master=master)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """Weight decay only on matrices (not norms/bias/1-d params)."""
    name = str(getattr(path[-1], "key", getattr(path[-1], "name", "")))
    return not (name.startswith("ln") or name.startswith("b_")
                or name in ("final_norm", "norm", "q_norm", "kv_norm",
                            "lam", "A_log", "D", "dt_bias", "b"))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params
                 ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    flat_g = jax.tree_util.tree_flatten_with_path(grads)[0]
    decay_flags = [_decay_mask(p) for p, _ in flat_g]
    treedef = jax.tree.structure(grads)
    decay_tree = jax.tree_util.tree_unflatten(treedef, decay_flags)
    masters = state.master if state.master is not None else params

    def upd(g, m, v, p, w, dec):
        # p: weights used in fwd (possibly bf16); w: fp32 master
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if dec:
            delta = delta + cfg.weight_decay * w.astype(jnp.float32)
        new_w = w.astype(jnp.float32) - lr * delta
        return new_w.astype(p.dtype), m, v, new_w

    out = jax.tree.map(upd, grads, state.m, state.v, params, masters,
                       decay_tree)
    is4 = lambda t: isinstance(t, tuple) and len(t) == 4
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is4)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is4)
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is4)
    new_master = (jax.tree.map(lambda t: t[3], out, is_leaf=is4)
                  if state.master is not None else None)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v, new_master), metrics

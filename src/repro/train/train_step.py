"""Canonical jitted train/serve steps with sharding annotations.

`make_train_step(cfg, opt_cfg, mesh)` returns a jitted function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with in/out shardings derived from `parallel.sharding`, donated params and
optimizer state, and optional microbatch gradient accumulation and int8
gradient compression (shard_map all-reduce) — the distributed-optimization
knobs used by the trainer and the dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.parallel import sharding
from repro.train import optimizer as opt


def loss_and_grad(cfg, params, batch):
    return jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch), has_aux=True)(params)


def make_train_step(cfg, opt_cfg: opt.AdamWConfig, mesh: Mesh,
                    microbatch: int = 0,
                    grad_compression: Optional[str] = None,
                    sequence_parallel: bool = False,
                    cast_params: Optional[str] = None):
    """microbatch > 0 splits the per-step batch into that many accumulation
    chunks. grad_compression: None | "int8" (see parallel.compression).
    cast_params="bfloat16" casts the (FSDP-sharded) fp32 master weights to
    bf16 *before* the per-layer all-gather, halving the dominant
    parameter-gather and gradient-reduce collective bytes (§Perf iteration
    1); the optimizer still updates fp32 masters."""

    def step(params, opt_state, batch):
        return _step_inner(params, opt_state, batch)

    def _step_inner(params, opt_state, batch):
        master = params
        if cast_params:
            dt = jnp.dtype(cast_params)
            params = jax.tree.map(
                lambda p: p.astype(dt) if p.dtype == jnp.float32 else p,
                params)
        if microbatch and microbatch > 1:
            def mb_slice(x, i):
                mb = x.shape[0] // microbatch
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def body(carry, i):
                gsum, aux_sum = carry
                mbatch = jax.tree.map(lambda x: mb_slice(x, i), batch)
                (l, m), g = loss_and_grad(cfg, params, mbatch)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, aux_sum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0.0)),
                jnp.arange(microbatch))
            grads = jax.tree.map(lambda g: g / microbatch, gsum)
            loss = lsum / microbatch
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = loss_and_grad(cfg, params, batch)

        if grad_compression == "int8":
            from repro.parallel import compression
            grads = compression.fake_requantize(grads)

        params2, opt2, om = opt.adamw_update(opt_cfg, grads, opt_state,
                                             master)
        metrics = dict(metrics)
        metrics.update(om)
        return params2, opt2, metrics

    def step_with_policy(params, opt_state, batch):
        with sharding.activation_policy(
                mesh, sequence_parallel=sequence_parallel, cfg=cfg):
            return _step_inner(params, opt_state, batch)

    step = step_with_policy

    def shard_for(tree_abs):
        return sharding.param_shardings(tree_abs, cfg, mesh)

    def jit_step(params_abs, opt_abs, batch_abs):
        pspec = shard_for(params_abs)
        ospec = opt.AdamWState(
            step=sharding.replicated(mesh),
            m=shard_for(opt_abs.m), v=shard_for(opt_abs.v),
            master=(shard_for(opt_abs.master)
                    if opt_abs.master is not None else None))
        bspec = sharding.batch_shardings(batch_abs, mesh, cfg)
        mspec = None  # metrics replicated
        return jax.jit(
            step,
            in_shardings=(pspec, ospec, bspec),
            out_shardings=(pspec, ospec, mspec),
            donate_argnums=(0, 1),
        )

    return step, jit_step


# ---------------------------------------------------------------------------
# serve steps (used by the dry-run for decode shapes and by the engine)
# ---------------------------------------------------------------------------
def make_serve_step(cfg, mesh: Mesh, kind: str = "decode"):
    """kind: "decode" (one token, KV cache donated) | "prefill"."""

    if kind == "decode":
        def step(params, token, pos, caches, kv_valid):
            with sharding.activation_policy(mesh):
                logits, caches = lm.decode_step(params, cfg, token, pos,
                                                caches, kv_valid=kv_valid)
            return logits, caches
    else:
        def step(params, tokens, caches, prefix_embeds=None):
            with sharding.activation_policy(mesh):
                return lm.prefill(params, cfg, tokens, caches,
                                  prefix_embeds=prefix_embeds)

    def jit_step(params_abs, caches_abs, token_abs=None, prefix_abs=None):
        pspec = sharding.param_shardings(params_abs, cfg, mesh)
        cspec = sharding.cache_shardings(caches_abs, cfg, mesh)

        def bsp(x):
            return NamedSharding(
                mesh, sharding.batch_spec(mesh, np.ndim(x), np.shape(x)))

        if kind == "decode":
            tok = (token_abs if token_abs is not None
                   else jax.ShapeDtypeStruct((1,), jnp.int32))
            return jax.jit(
                step,
                in_shardings=(pspec, bsp(tok), None, cspec,
                              bsp(jax.ShapeDtypeStruct(
                                  (tok.shape[0],), jnp.int32))),
                out_shardings=(bsp(tok), cspec),
                donate_argnums=(3,),
            )
        tok = (token_abs if token_abs is not None
               else jax.ShapeDtypeStruct((1, 8), jnp.int32))
        ins = (pspec, bsp(tok), cspec)
        if prefix_abs is not None:
            ins = ins + (bsp(prefix_abs),)
        return jax.jit(
            step,
            in_shardings=ins,
            out_shardings=(None, cspec),
            donate_argnums=(2,),
        )

    return step, jit_step

"""Fault-tolerant training loop.

Production concerns implemented here (all exercised by tests on CPU):
  * checkpoint/restart: async atomic checkpoints every `ckpt_every` steps;
    `Trainer.fit` resumes from the latest checkpoint automatically.
  * failure handling: any step exception triggers restore-from-checkpoint
    and (optionally) an elastic re-mesh with the surviving device count;
    `inject_failure_at` simulates node loss in tests.
  * straggler mitigation: per-step wall times tracked with an EWMA; outliers
    (z > threshold) raise a straggler event. The *decision* of whether to
    run the expensive re-shard planning is gated by the paper's DAS
    machinery (fast path = keep going, slow path = re-plan) — see
    `DASGate`: a depth-2 decision tree over (event rate, step-time
    inflation), mirroring core.das at the cluster-scheduling level.
  * the loop never blocks on I/O: data prefetch + async checkpointer.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.models import lm
from repro.train import optimizer as optim
from repro.train import train_step as ts


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    log_every: int = 10
    microbatch: int = 0
    grad_compression: Optional[str] = None
    straggler_z: float = 3.0
    straggler_ewma: float = 0.9
    max_restarts: int = 3


class DASGate:
    """DAS-style fast/slow gate for the re-shard planning decision.

    Features: (straggler-event rate, relative step-time inflation).
    Fast path (LUT analog): keep the current plan — O(ns) decision.
    Slow path (ETF analog): run `replan` — expensive global planning.
    The depth-2 thresholds play the role of the trained classifier; they can
    be refit from logged events via core.classifier.DecisionTree.
    """

    def __init__(self, rate_thr: float = 0.2, inflation_thr: float = 1.5,
                 replan: Optional[Callable[[], None]] = None):
        self.rate_thr = rate_thr
        self.inflation_thr = inflation_thr
        self.replan = replan
        self.events = 0
        self.decisions = 0
        self.slow_calls = 0

    def decide(self, event_rate: float, inflation: float) -> str:
        self.decisions += 1
        if event_rate >= self.rate_thr and inflation >= self.inflation_thr:
            self.slow_calls += 1
            if self.replan is not None:
                self.replan()
            return "slow"
        return "fast"


class Trainer:
    def __init__(self, cfg, model_cfg, opt_cfg: optim.AdamWConfig,
                 mesh, data: Iterator, seed: int = 0):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.mesh = mesh
        self.data = data
        self.seed = seed
        self.ckpter = ckpt.AsyncCheckpointer(cfg.ckpt_dir)
        self.gate = DASGate()
        self.inject_failure_at: Optional[int] = None
        self.metrics_log: list = []
        self.straggler_events = 0

    # -- setup ---------------------------------------------------------------
    def init_state(self):
        key = jax.random.PRNGKey(self.seed)
        params = lm.lm_init(key, self.model_cfg)
        opt_state = optim.adamw_init(params)
        return params, opt_state

    def _compile(self, params, opt_state, batch):
        _, jit_builder = ts.make_train_step(
            self.model_cfg, self.opt_cfg, self.mesh,
            microbatch=self.cfg.microbatch,
            grad_compression=self.cfg.grad_compression)
        abstract = lambda t: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype), t)
        return jit_builder(abstract(params), abstract(opt_state),
                           abstract(batch))

    # -- main loop -----------------------------------------------------------
    def fit(self, resume: bool = True) -> Dict[str, Any]:
        params, opt_state = self.init_state()
        start_step = 0
        if resume and ckpt.latest_step(self.cfg.ckpt_dir) is not None:
            (params, opt_state), start_step, _ = self._restore(
                (params, opt_state))
        restarts = 0
        step = start_step
        ewma, ewvar = None, 0.0
        compiled = None
        if hasattr(self.data, "set_step"):
            self.data.set_step(step)
        data_it = iter(self.data)

        while step < self.cfg.total_steps:
            try:
                batch = next(data_it)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                if compiled is None:
                    compiled = self._compile(params, opt_state, batch)
                if (self.inject_failure_at is not None
                        and step == self.inject_failure_at):
                    self.inject_failure_at = None
                    raise RuntimeError("injected node failure")
                t0 = time.perf_counter()
                params, opt_state, metrics = compiled(params, opt_state,
                                                      batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.perf_counter() - t0

                # straggler detection (EWMA z-score on step time)
                if ewma is None:
                    ewma = dt
                else:
                    d = dt - ewma
                    a = 1 - self.cfg.straggler_ewma
                    ewma += a * d
                    ewvar = (1 - a) * (ewvar + a * d * d)
                    z = d / (np.sqrt(ewvar) + 1e-9)
                    if z > self.cfg.straggler_z and step > start_step + 5:
                        self.straggler_events += 1
                        rate = self.straggler_events / max(
                            step - start_step, 1)
                        self.gate.decide(rate, dt / ewma)

                step += 1
                metrics["step"] = step
                metrics["step_time_s"] = dt
                self.metrics_log.append(metrics)
                if step % self.cfg.log_every == 0:
                    print(f"step {step:6d} loss {metrics.get('loss', 0):.4f}"
                          f" lr {metrics.get('lr', 0):.2e} {dt*1e3:.0f}ms")
                if step % self.cfg.ckpt_every == 0:
                    self.ckpter.save_async((params, opt_state), step,
                                           meta={"seed": self.seed})
                    ckpt.prune_old(self.cfg.ckpt_dir, self.cfg.keep_ckpts)
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise
                print(f"[trainer] step {step} failed ({e}); "
                      f"restart {restarts}/{self.cfg.max_restarts}")
                self.ckpter.wait()
                if ckpt.latest_step(self.cfg.ckpt_dir) is not None:
                    (params, opt_state), step, _ = self._restore(
                        (params, opt_state))
                else:
                    params, opt_state = self.init_state()
                    step = 0
                if hasattr(self.data, "set_step"):
                    self.data.set_step(step)
                data_it = iter(self.data)
                compiled = None  # re-jit (elastic: mesh may have changed)

        self.ckpter.wait()
        self.ckpter.save_async((params, opt_state), step,
                               meta={"seed": self.seed})
        self.ckpter.wait()
        return {
            "params": params, "opt_state": opt_state, "step": step,
            "metrics": self.metrics_log, "restarts": restarts,
            "straggler_events": self.straggler_events,
            "gate": (self.gate.decisions, self.gate.slow_calls),
        }

    def _restore(self, like):
        from repro.parallel import sharding as sh
        params_like, opt_like = like
        specs = (sh.param_shardings(params_like, self.model_cfg, self.mesh),
                 optim.AdamWState(
                     step=sh.replicated(self.mesh),
                     m=sh.param_shardings(opt_like.m, self.model_cfg,
                                          self.mesh),
                     v=sh.param_shardings(opt_like.v, self.model_cfg,
                                          self.mesh)))
        tree, step, meta = ckpt.restore(self.cfg.ckpt_dir, like,
                                        shardings=specs)
        return tree, step, meta

"""Continuous-batching serving engine with pluggable (DAS) dispatch.

The engine maintains R replicas, each with a wait queue and a running
decode batch (continuous batching: new requests are admitted into the batch
between decode iterations, paying their prefill on admission). The executor
clock comes from the roofline cost model (costmodel.py — the same terms the
§Roofline analysis uses); the jitted prefill/decode model steps themselves
are exercised by `lm.prefill`/`lm.decode_step` integration tests and the
dry-run decode cells, so the engine's scheduling layer and the model
execution layer are each validated where they are observable.

The dispatcher (serve.dispatch) decides request -> replica. Dispatch is a
serial resource with policy-dependent latency, exactly like the paper's
scheduler core: the fast LUT path is O(1); the slow ETF path walks every
replica's queue with the cost model. At high request rates the ETF
dispatcher itself becomes the bottleneck — the DAS preselection classifier
arbitrates per request.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

import numpy as np

from repro.serve import costmodel as cm


@dataclasses.dataclass
class Request:
    rid: int
    arrival_s: float
    prompt_len: int
    gen_len: int
    # filled by the engine
    replica: int = -1
    dispatched_s: float = -1.0
    first_token_s: float = -1.0
    done_s: float = -1.0
    tokens_out: int = 0


@dataclasses.dataclass
class EngineConfig:
    n_replicas: int = 4
    max_batch: int = 16
    max_ctx: int = 4096
    execute: str = "sim"


class Replica:
    def __init__(self, idx: int, spec: cm.ReplicaSpec, mc: cm.ModelCost,
                 max_batch: int):
        self.idx = idx
        self.spec = spec
        self.mc = mc
        self.max_batch = max_batch
        self.queue: List[Request] = []
        self.running: List[Request] = []
        self.free_at = 0.0
        self.busy_s = 0.0
        self.energy_j = 0.0

    # -- ETF estimate: when would `req` finish here? -------------------------
    def estimate_finish(self, req: Request, now: float) -> float:
        t = max(self.free_at, now)
        # queued work ahead of us (prefill + its remaining decode, batched)
        for r in self.queue:
            t += cm.prefill_seconds(self.mc, self.spec, r.prompt_len)
        backlog = sum(max(r.gen_len - r.tokens_out, 0)
                      for r in self.running + self.queue)
        nb = max(len(self.running) + len(self.queue), 1)
        steps = backlog / nb
        t += steps * cm.decode_step_seconds(
            self.mc, self.spec, nb, self.mean_ctx())
        t += cm.prefill_seconds(self.mc, self.spec, req.prompt_len)
        t += req.gen_len * cm.decode_step_seconds(
            self.mc, self.spec, min(nb + 1, self.max_batch), self.mean_ctx())
        return t

    def mean_ctx(self) -> float:
        rs = self.running
        if not rs:
            return 1.0
        return float(np.mean([r.prompt_len + r.tokens_out for r in rs]))

    def load(self) -> float:
        return (sum(max(r.gen_len - r.tokens_out, 0)
                    for r in self.running + self.queue))

    # -- one continuous-batching iteration -----------------------------------
    def step(self, now: float) -> float:
        """Advance one iteration starting at `now`; returns its duration."""
        dt = 0.0
        # admit from queue
        while self.queue and len(self.running) < self.max_batch:
            r = self.queue.pop(0)
            pf = cm.prefill_seconds(self.mc, self.spec, r.prompt_len)
            dt += pf
            r.first_token_s = now + dt
            r.tokens_out = 1
            self.running.append(r)
        if self.running:
            step_t = cm.decode_step_seconds(
                self.mc, self.spec, len(self.running), self.mean_ctx())
            dt += step_t
            done = []
            for r in self.running:
                r.tokens_out += 1
                if r.tokens_out >= r.gen_len:
                    r.done_s = now + dt
                    done.append(r)
            self.running = [r for r in self.running if r not in done]
        self.busy_s += dt
        self.energy_j += cm.step_energy_j(self.spec, dt, busy=True)
        return dt


@dataclasses.dataclass
class ServeResult:
    requests: List[Request]
    mean_latency_s: float
    p99_latency_s: float
    mean_ttft_s: float
    throughput_rps: float
    energy_j: float
    edp: float
    dispatch_fast: int
    dispatch_slow: int
    dispatch_busy_s: float
    makespan_s: float


def run_engine(requests: List[Request], dispatcher, cfg: EngineConfig,
               spec: cm.ReplicaSpec, mc: cm.ModelCost) -> ServeResult:
    """Event-driven serving simulation with a serial dispatcher."""
    reps = [Replica(i, spec, mc, cfg.max_batch)
            for i in range(cfg.n_replicas)]
    # event heap: (time, seq, kind, payload)
    ev: List = []
    seqno = 0
    for r in sorted(requests, key=lambda r: r.arrival_s):
        heapq.heappush(ev, (r.arrival_s, seqno, "arrive", r))
        seqno += 1
    disp_free = 0.0
    disp_busy = 0.0
    n_fast = n_slow = 0
    rep_next: Dict[int, float] = {}

    def schedule_rep(i: int, t: float):
        nonlocal seqno
        if rep_next.get(i, -1.0) < t:
            rep_next[i] = t
            heapq.heappush(ev, (t, seqno, "step", i))
            seqno += 1

    now = 0.0
    while ev:
        now, _, kind, payload = heapq.heappop(ev)
        if kind == "arrive":
            req: Request = payload
            t0 = max(now, disp_free)
            choice, lat = dispatcher.dispatch(req, reps, now)
            disp_free = t0 + lat
            disp_busy += lat
            if dispatcher.last_was_slow:
                n_slow += 1
            else:
                n_fast += 1
            req.replica = choice
            req.dispatched_s = disp_free
            heapq.heappush(ev, (disp_free, seqno, "enqueue", req))
            seqno += 1
        elif kind == "enqueue":
            req = payload
            reps[req.replica].queue.append(req)
            schedule_rep(req.replica, max(now, reps[req.replica].free_at))
        else:  # replica step
            i = payload
            rep = reps[i]
            if rep.queue or rep.running:
                start = max(now, rep.free_at)
                dt = rep.step(start)
                rep.free_at = start + dt
                if rep.queue or rep.running:
                    schedule_rep(i, rep.free_at)

    done = [r for r in requests if r.done_s >= 0]
    lat = np.array([r.done_s - r.arrival_s for r in done]) if done else \
        np.array([np.inf])
    ttft = np.array([r.first_token_s - r.arrival_s for r in done]) if done \
        else np.array([np.inf])
    makespan = max((r.done_s for r in done), default=0.0)
    energy = sum(r.energy_j for r in reps)
    # idle energy for the makespan
    for rep in reps:
        energy += cm.step_energy_j(spec, max(makespan - rep.busy_s, 0.0),
                                   busy=False)
    mean_lat = float(lat.mean())
    return ServeResult(
        requests=requests,
        mean_latency_s=mean_lat,
        p99_latency_s=float(np.percentile(lat, 99)),
        mean_ttft_s=float(ttft.mean()),
        throughput_rps=len(done) / makespan if makespan else 0.0,
        energy_j=float(energy),
        edp=float(energy) * mean_lat,
        dispatch_fast=n_fast,
        dispatch_slow=n_slow,
        dispatch_busy_s=disp_busy,
        makespan_s=makespan,
    )


def poisson_requests(rate_rps: float, n: int, seed: int = 0,
                     prompt_mean: int = 512, gen_mean: int = 64
                     ) -> List[Request]:
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    gaps[0] = 0.0
    t = np.cumsum(gaps)
    out = []
    for i in range(n):
        out.append(Request(
            rid=i, arrival_s=float(t[i]),
            prompt_len=int(np.clip(rng.lognormal(np.log(prompt_mean), 0.6),
                                   16, 8192)),
            gen_len=int(np.clip(rng.lognormal(np.log(gen_mean), 0.5),
                                4, 1024)),
        ))
    return out

"""Request dispatchers: LUT (fast), ETF (slow), DAS (preselected), and the
static-threshold heuristic — the paper's scheduler set transplanted to
serving. The DAS classifier is the same depth-2 decision tree machinery
(core.classifier), trained by the same two-execution oracle protocol
(serve.oracle) on features (request arrival rate, earliest replica
availability).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import classifier as clf
from repro.serve import costmodel as cm

# dispatch-path latencies (host-side, seconds): LUT is an O(1) table probe;
# ETF walks every replica queue with the cost model (scales with queued
# requests); the DAS classifier itself is prefetched/off-path (paper III-B).
LUT_LATENCY = 2e-6
ETF_BASE = 2e-5
ETF_PER_ITEM = 4e-6


class _RateTracker:
    """8-entry arrival shift register (paper's data-rate counter)."""

    def __init__(self):
        self.ring = [0.0] * 8
        self.n = 0

    def observe(self, t: float):
        self.ring[self.n % 8] = t
        self.n += 1

    def rate(self) -> float:
        if self.n < 2:
            return 0.0
        c = min(self.n, 8)
        ts = sorted(self.ring[:c])
        span = ts[-1] - ts[0]
        return (c - 1) / span if span > 0 else 0.0


def _features(req, replicas, now, rate) -> np.ndarray:
    avail = min(max(r.free_at - now, 0.0) for r in replicas)
    qlen = sum(len(r.queue) + len(r.running) for r in replicas)
    return np.array([rate, avail, qlen, req.prompt_len, req.gen_len],
                    np.float32)


FEAT_NAMES = ("arrival_rate", "earliest_replica_avail", "total_queued",
              "prompt_len", "gen_len")
PAPER_FEATURES = (0, 1)   # rate + earliest availability, as in the paper


class LUTDispatcher:
    """O(1): static bucket table (by prompt-size class) -> replica,
    round-robin within bucket. The serving analog of 'most energy-efficient
    PE per task type': smallest adequate replica, no queue inspection."""

    name = "LUT"

    def __init__(self, n_replicas: int):
        self.n = n_replicas
        self.rr = [0] * 4
        self.last_was_slow = False

    def _bucket(self, req) -> int:
        return int(min(np.log2(max(req.prompt_len, 16)) - 4, 3))

    def dispatch(self, req, replicas, now):
        b = self._bucket(req)
        self.rr[b] = (self.rr[b] + 1) % self.n
        self.last_was_slow = False
        return (b + self.rr[b]) % self.n, LUT_LATENCY


class ETFDispatcher:
    """Slow/sophisticated: earliest-estimated-finish-time over replicas."""

    name = "ETF"

    def __init__(self):
        self.last_was_slow = True

    def dispatch(self, req, replicas, now):
        self.last_was_slow = True
        est = [r.estimate_finish(req, now) for r in replicas]
        n_items = sum(len(r.queue) + len(r.running) for r in replicas)
        lat = ETF_BASE + ETF_PER_ITEM * n_items
        return int(np.argmin(est)), lat


class DASDispatcher:
    """Depth-2 DT preselects LUT vs ETF per request (zero added latency:
    features are refreshed off the dispatch path, paper III-B)."""

    name = "DAS"

    def __init__(self, tree: clf.DecisionTree, n_replicas: int,
                 feature_ids=PAPER_FEATURES):
        self.tree = tree
        self.fast = LUTDispatcher(n_replicas)
        self.slow = ETFDispatcher()
        self.rt = _RateTracker()
        self.feature_ids = list(feature_ids)
        self.last_was_slow = False

    def dispatch(self, req, replicas, now):
        self.rt.observe(req.arrival_s)
        f = _features(req, replicas, now, self.rt.rate())
        use_slow = bool(self.tree.predict(
            f[self.feature_ids][None])[0])
        self.last_was_slow = use_slow
        if use_slow:
            return self.slow.dispatch(req, replicas, now)
        return self.fast.dispatch(req, replicas, now)


class ThresholdDispatcher:
    """Paper's heuristic baseline: rate below threshold -> LUT, else ETF."""

    name = "threshold"

    def __init__(self, rate_threshold: float, n_replicas: int):
        self.thr = rate_threshold
        self.fast = LUTDispatcher(n_replicas)
        self.slow = ETFDispatcher()
        self.rt = _RateTracker()
        self.last_was_slow = False

    def dispatch(self, req, replicas, now):
        self.rt.observe(req.arrival_s)
        use_slow = self.rt.rate() >= self.thr
        self.last_was_slow = use_slow
        if use_slow:
            return self.slow.dispatch(req, replicas, now)
        return self.fast.dispatch(req, replicas, now)


class OracleDispatcher:
    """First-execution instrumentation: computes both, follows LUT, logs
    agreement + features (paper Fig. 1)."""

    name = "oracle"

    def __init__(self, n_replicas: int):
        self.fast = LUTDispatcher(n_replicas)
        self.slow = ETFDispatcher()
        self.rt = _RateTracker()
        self.features: List[np.ndarray] = []
        self.agree: List[bool] = []
        self.last_was_slow = False

    def dispatch(self, req, replicas, now):
        self.rt.observe(req.arrival_s)
        self.features.append(_features(req, replicas, now, self.rt.rate()))
        cf, _ = self.fast.dispatch(req, replicas, now)
        cs, _ = self.slow.dispatch(req, replicas, now)
        self.agree.append(cf == cs)
        self.last_was_slow = False
        return cf, LUT_LATENCY


def train_das_dispatcher(scenarios, cfg, spec, mc,
                         feature_ids=PAPER_FEATURES,
                         metric: str = "mean_latency_s") -> DASDispatcher:
    """Two-execution oracle over (rate, seed) scenarios -> depth-2 DT."""
    from repro.serve import engine as eng
    X: List[np.ndarray] = []
    y: List[np.ndarray] = []
    for rate, n, seed in scenarios:
        reqs1 = eng.poisson_requests(rate, n, seed)
        orc = OracleDispatcher(cfg.n_replicas)
        r1 = eng.run_engine(reqs1, orc, cfg, spec, mc)
        reqs2 = eng.poisson_requests(rate, n, seed)
        r2 = eng.run_engine(reqs2, ETFDispatcher(), cfg, spec, mc)
        pending = 1 if getattr(r2, metric) < getattr(r1, metric) else 0
        lab = np.where(np.array(orc.agree), 0, pending)
        X.append(np.stack(orc.features))
        y.append(lab)
    Xa = np.concatenate(X)
    ya = np.concatenate(y).astype(np.int32)
    cols = list(feature_ids)
    tree = clf.DecisionTree.fit(Xa[:, cols], ya, depth=2, feature_ids=cols)
    d = DASDispatcher(tree, cfg.n_replicas, feature_ids=cols)
    d.train_accuracy = tree.accuracy(Xa[:, cols], ya)
    d.label_slow_frac = float(ya.mean())
    return d

"""Roofline cost model for serving: per-replica prefill/decode step times
and energy, derived from the arch config + TPU v5e constants (the same
numbers the §Roofline analysis uses). The ETF dispatcher's finish-time
estimates and the simulated executor clock both come from here."""
from __future__ import annotations

import dataclasses

from repro.launch import mesh as meshlib


@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """A serving replica = a device group running one model instance."""
    name: str
    n_chips: int = 8
    peak_flops: float = meshlib.PEAK_FLOPS_BF16
    hbm_bw: float = meshlib.HBM_BW
    power_w: float = 200.0          # per chip, busy
    idle_w: float = 60.0
    efficiency: float = 0.5         # fraction-of-roofline actually achieved


@dataclasses.dataclass(frozen=True)
class ModelCost:
    """Active params + per-token KV bytes determine the roofline terms."""
    n_active_params: float
    kv_bytes_per_token: float       # across all layers
    param_bytes: float

    @staticmethod
    def from_config(cfg) -> "ModelCost":
        # rough active-param count (exact one comes from lm.param_count)
        d, L, f, v = cfg.d_model, cfg.n_layers, cfg.d_ff, cfg.vocab
        if cfg.mlp_type == "moe":
            mc = cfg.moe
            f_eff = mc.d_expert * (mc.top_k + mc.n_shared)
        elif cfg.mlp_type == "none":
            f_eff = 2 * d * cfg.ssd.expand if cfg.ssd else 2 * d
        else:
            f_eff = f
        per_layer = 4 * d * d + 3 * d * f_eff
        n = L * per_layer + 2 * v * d
        if cfg.attn_impl == "mla":
            kv = L * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * 2
        elif cfg.ssd is not None:
            kv = 0.0
        else:
            kv = L * cfg.n_kv_heads * cfg.d_head * 2 * 2
        return ModelCost(n_active_params=float(n),
                         kv_bytes_per_token=float(kv),
                         param_bytes=float(n) * 2)


def prefill_seconds(mc: ModelCost, rs: ReplicaSpec, n_tokens: int) -> float:
    flops = 2.0 * mc.n_active_params * n_tokens
    t_compute = flops / (rs.n_chips * rs.peak_flops * rs.efficiency)
    t_mem = mc.param_bytes / (rs.n_chips * rs.hbm_bw)
    return max(t_compute, t_mem)


def decode_step_seconds(mc: ModelCost, rs: ReplicaSpec, batch: int,
                        mean_ctx: float) -> float:
    flops = 2.0 * mc.n_active_params * batch
    t_compute = flops / (rs.n_chips * rs.peak_flops * rs.efficiency)
    bytes_moved = (mc.param_bytes
                   + batch * mean_ctx * mc.kv_bytes_per_token)
    t_mem = bytes_moved / (rs.n_chips * rs.hbm_bw)
    return max(t_compute, t_mem)


def step_energy_j(rs: ReplicaSpec, seconds: float, busy: bool) -> float:
    w = rs.power_w if busy else rs.idle_w
    return rs.n_chips * w * seconds

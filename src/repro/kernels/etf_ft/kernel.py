"""ETF finish-time search kernel (TPU Pallas) — the paper's own hot spot.

Algorithm 1's inner search computes FT[r, p] = max(avail[r, p], free[p],
now) + exec[r, p] over (ready tasks x PEs) and takes the argmin. On the
DSSoC this runs on a Cortex-A53 in ~65 ns; the TPU-native adaptation is a
dense masked min-reduction:

  * PE axis padded to the 128-lane VPU width, ready axis tiled by block_r
    (sublane-aligned),
  * one fused pass computes FT and a flat argmin via an index-encoded
    min-reduction (value * P + index packing avoided: we reduce value and
    index side by side),
  * grid = (n_batch,) for vmapped scheduling sweeps (the simulator's
    40-workload x 14-rate evaluation runs thousands of independent
    decisions).

inf entries (PE cannot run the task type / empty ready slots) never win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38


def _etf_kernel(avail_ref, free_ref, exec_ref, now_ref, out_ref):
    avail = avail_ref[0]                       # [R, P]
    free = free_ref[0]                         # [1, P]
    exec_t = exec_ref[0]                       # [R, P]
    now = now_ref[0, 0]
    ft = jnp.maximum(jnp.maximum(avail, free), now) + exec_t
    ft = jnp.where(jnp.isfinite(ft), ft, BIG)
    flat = ft.reshape(-1)
    idx = jnp.argmin(flat)
    out_ref[0, 0] = flat[idx]
    out_ref[0, 1] = idx.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def etf_ft_search(avail, free, exec_t, now, *, interpret=False):
    """avail [B, R, P], free [B, P], exec_t [B, R, P], now [B].
    Returns (ft_min [B], slot [B], pe [B]). Lanes padded to 128."""
    B, R, P = avail.shape
    Pp = max(128, -(-P // 128) * 128)
    pad = ((0, 0), (0, 0), (0, Pp - P))
    avail_p = jnp.pad(avail, pad, constant_values=jnp.inf)
    exec_p = jnp.pad(exec_t, pad, constant_values=jnp.inf)
    free_p = jnp.pad(free[:, None, :], pad, constant_values=jnp.inf)

    out = pl.pallas_call(
        _etf_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, R, Pp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, Pp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, R, Pp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 2), jnp.float32),
        interpret=interpret,
    )(avail_p, free_p, exec_p, now[:, None])

    ft_min = out[:, 0]
    flat_idx = out[:, 1].astype(jnp.int32)
    return ft_min, flat_idx // Pp, flat_idx % Pp

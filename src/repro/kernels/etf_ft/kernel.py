"""ETF finish-time search kernels (TPU Pallas) — the paper's own hot spot.

Algorithm 1's inner search computes FT[r, p] = max(avail[r, p], free[p],
now) + exec[r, p] over (ready tasks x PEs) and takes the argmin. On the
DSSoC this runs on a Cortex-A53 in ~65 ns; the TPU-native adaptation is a
dense masked min-reduction:

  * PE axis padded to the 128-lane VPU width, ready axis tiled by block_r
    (sublane-aligned),
  * one fused pass computes FT and a flat argmin via an index-encoded
    min-reduction (value * P + index packing avoided: we reduce value and
    index side by side),
  * grid = (n_batch,) for vmapped scheduling sweeps (the simulator's
    40-workload x 14-rate evaluation runs thousands of independent
    decisions).

inf entries (PE cannot run the task type / empty ready slots) never win.

Two kernels serve the simulator's decision hot path (dispatched by
`ops.py`, knob `REPRO_SIM_KERNELS`):

  * `etf_ft_search_masked` — the scenario-batched decision search with
    per-lane `slot_ok` / `pe_alive` masks and a degraded-mode feasibility
    flag. The tie-break contract is the simulator's: the FIRST global
    minimum of the flattened [R, P] finish-time matrix wins, exactly as
    `jnp.argmin` over the inf-masked matrix does, so the kernel-backed
    decision path is bit-exact against the inline jnp path.
  * `push_rows` — the push-time availability rows: for each newly-ready
    task the max over its predecessors of (pred finish + NoC transfer
    when the predecessor ran on a different cluster), fused over the
    [K, MP, P] contribution tensor in one pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38
LANES = 128         # VPU lane width: the PE axis pads up to this
SUBLANES = 8        # f32 sublane tile height (ready axis alignment)

# One grid step of the search kernel owns a [R, Pp] block. Interpret mode
# evaluates the grid with a Python interpreter, so its cost scales with
# the total number of block cells, not the batch count — the budget below
# is 64 grid steps of the default [64, 128] block, which reproduces the
# old `B > 64` bailout at that geometry instead of hard-coding a batch
# count that silently lies for other block shapes.
MAX_INTERPRET_CELLS = 64 * 64 * LANES


def _pad_lanes(p: int) -> int:
    return max(LANES, -(-p // LANES) * LANES)


def _etf_kernel(avail_ref, free_ref, exec_ref, now_ref, out_ref):
    avail = avail_ref[0]                       # [R, P]
    free = free_ref[0]                         # [1, P]
    exec_t = exec_ref[0]                       # [R, P]
    now = now_ref[0, 0]
    ft = jnp.maximum(jnp.maximum(avail, free), now) + exec_t
    ft = jnp.where(jnp.isfinite(ft), ft, BIG)
    flat = ft.reshape(-1)
    idx = jnp.argmin(flat)
    out_ref[0, 0] = flat[idx]
    out_ref[0, 1] = idx.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def etf_ft_search(avail, free, exec_t, now, *, interpret=False):
    """avail [B, R, P], free [B, P], exec_t [B, R, P], now [B].
    Returns (ft_min [B], slot [B], pe [B]). Lanes padded to 128."""
    B, R, P = avail.shape
    Pp = _pad_lanes(P)
    pad = ((0, 0), (0, 0), (0, Pp - P))
    avail_p = jnp.pad(avail, pad, constant_values=jnp.inf)
    exec_p = jnp.pad(exec_t, pad, constant_values=jnp.inf)
    free_p = jnp.pad(free[:, None, :], pad, constant_values=jnp.inf)

    out = pl.pallas_call(
        _etf_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, R, Pp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, Pp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, R, Pp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 2), jnp.float32),
        interpret=interpret,
    )(avail_p, free_p, exec_p, now[:, None])

    ft_min = out[:, 0]
    flat_idx = out[:, 1].astype(jnp.int32)
    return ft_min, flat_idx // Pp, flat_idx % Pp


# ---------------------------------------------------------------------------
# scenario-batched masked decision search
# ---------------------------------------------------------------------------
def _etf_masked_kernel(avail_ref, free_ref, exec_ref, now_ref, sok_ref,
                       alive_ref, out_ref):
    avail = avail_ref[0]                       # [R, Pp]
    free = free_ref[0]                         # [1, Pp]
    exec_t = exec_ref[0]                       # [R, Pp]
    now = now_ref[0, 0]
    sok = sok_ref[0]                           # [R] f32 0/1
    alive = alive_ref[0]                       # [1, Pp] f32 0/1
    ft = jnp.maximum(jnp.maximum(avail, free), now) + exec_t
    ok = (sok[:, None] > 0) & (alive > 0) & jnp.isfinite(ft)
    ft = jnp.where(ok, ft, BIG)
    flat = ft.reshape(-1)
    idx = jnp.argmin(flat)
    out_ref[0, 0] = flat[idx]
    out_ref[0, 1] = idx.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def etf_ft_search_masked(avail, free, exec_t, now, slot_ok, pe_alive, *,
                         interpret=False):
    """Scenario-batched masked search: avail/exec_t [S, R, P], free [S, P],
    now [S], slot_ok [S, R] bool, pe_alive [S, P] bool.

    Returns (ft_min [S], slot [S], pe [S], feasible [S]): the first global
    minimum of the masked finish-time matrix per scenario (identical index
    to `jnp.argmin` over the inf-masked matrix — slot 0 / pe 0 when every
    candidate is masked, in which case `feasible` is False).
    """
    S, R, P = avail.shape
    Pp = _pad_lanes(P)
    pad = ((0, 0), (0, 0), (0, Pp - P))
    avail_p = jnp.pad(avail, pad, constant_values=jnp.inf)
    exec_p = jnp.pad(exec_t, pad, constant_values=jnp.inf)
    free_p = jnp.pad(free[:, None, :], pad, constant_values=jnp.inf)
    alive_p = jnp.pad(pe_alive.astype(jnp.float32)[:, None, :], pad)
    sok = slot_ok.astype(jnp.float32)

    out = pl.pallas_call(
        _etf_masked_kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, R, Pp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, Pp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, R, Pp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, R), lambda b: (b, 0)),
            pl.BlockSpec((1, 1, Pp), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((S, 2), jnp.float32),
        interpret=interpret,
    )(avail_p, free_p, exec_p, now[:, None], sok, alive_p)

    ft_min = out[:, 0]
    flat_idx = out[:, 1].astype(jnp.int32)
    return ft_min, flat_idx // Pp, flat_idx % Pp, ft_min < BIG


# ---------------------------------------------------------------------------
# push-time availability rows (the [K, MP, P] NoC-contribution max)
# ---------------------------------------------------------------------------
def _push_kernel(pfin_ref, cost_ref, pcl_ref, pv_ref, pecl_ref, base_ref,
                 out_ref):
    pfin = pfin_ref[0]                         # [K, MP]
    cost = cost_ref[0]                         # [K, MP]
    pcl = pcl_ref[0]                           # [K, MP] f32 cluster ids
    pv = pv_ref[0]                             # [K, MP] f32 0/1
    pecl = pecl_ref[0]                         # [Pp] f32 cluster ids
    base = base_ref[0]                         # [K]
    cross = (pcl[:, :, None] != pecl[None, None, :]).astype(jnp.float32)
    contrib = jnp.where(pv[:, :, None] > 0,
                        pfin[:, :, None] + cost[:, :, None] * cross,
                        -BIG)                  # [K, MP, Pp]
    out_ref[0] = jnp.maximum(contrib.max(axis=1), base[:, None])


@functools.partial(jax.jit, static_argnames=("interpret",))
def push_rows(pfin, cost, pcl, pv, pe_cluster, bases, *, interpret=False):
    """Scenario-batched push-time rows: pfin/cost/pcl/pv [S, K, MP]
    (pred finish, NoC transfer cost, pred cluster, validity), pe_cluster
    [P], bases [S, K]. Returns rows [S, K, P]:

      rows[s, k, p] = max(max_m over valid preds of
                          (pfin + cost * (pcl != cluster(p)))), bases[s, k])

    exactly the simulator's `_avail_rows` contribution max.
    """
    S, K, MP = pfin.shape
    P = pe_cluster.shape[0]
    Pp = _pad_lanes(P)
    pecl = jnp.pad(pe_cluster.astype(jnp.float32), (0, Pp - P),
                   constant_values=-1.0)[None, :]

    out = pl.pallas_call(
        _push_kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, K, MP), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, K, MP), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, K, MP), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, K, MP), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Pp), lambda b: (0, 0)),
            pl.BlockSpec((1, K), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, K, Pp), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, K, Pp), jnp.float32),
        interpret=interpret,
    )(pfin, cost, pcl.astype(jnp.float32), pv.astype(jnp.float32), pecl,
      bases)
    return out[:, :, :P]

"""Jit'd wrapper for the ETF finish-time search kernel."""
from __future__ import annotations

import jax

from repro.kernels.etf_ft import kernel, ref


def etf_ft(avail, free, exec_t, now, *, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if interpret and avail.shape[0] > 64:
        return ref.etf_ft_reference(avail, free, exec_t, now)
    return kernel.etf_ft_search(avail, free, exec_t, now,
                                interpret=interpret)

"""Backend-aware dispatch for the decision-path kernels.

The simulator's decision hot path (`_etf_choice` / `_etf_choice_degraded`
/ `_avail_rows` in `core/simulator.py`) routes through this module when
the `REPRO_SIM_KERNELS` knob is on. Dispatch rule:

  ``REPRO_SIM_KERNELS`` =
    * ``0`` / ``off``      -> simulator keeps its inline jnp path
    * ``1`` / ``auto`` (default) -> Pallas kernels native on TPU, the
      single fused XLA formulation (`ref.py`) everywhere else
    * ``pallas``           -> force the Pallas kernels even off-TPU
      (interpret mode; slow — CI correctness runs only)

The resolved mode is threaded into the jit'd simulator as a *static*
argument by `run` / `run_batch` / `simulate_batch`, so flipping the env
var between calls dispatches correctly instead of hitting a stale trace.

Every path honours the same tie-break contract: the FIRST global minimum
of the flattened masked [R, P] finish-time matrix wins (bit-exact vs the
inline `jnp.argmin` path, including the all-masked -> slot 0 / pe 0
case), and the push-time rows are bitwise identical to the inline
[K, MP, P] contribution max.

`DISPATCH_COUNT` tallies which backend each decision primitive traced
through (trace-time, mirroring `sim.TRACE_COUNT`) — surfaced by
`benchmarks/run.py --json` so sweeps record which path actually ran.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.etf_ft import kernel, ref

#: trace-time tallies per (primitive, backend) — a jit cache hit adds
#: nothing, exactly like `sim.TRACE_COUNT`.
DISPATCH_COUNT = {
    "etf_xla": 0, "etf_pallas": 0, "etf_pallas_interpret": 0,
    "push_xla": 0, "push_pallas": 0, "push_pallas_interpret": 0,
    "etf_ft_ref_fallback": 0,
}

_OFF = ("0", "off", "no", "false")
_AUTO = ("1", "auto", "on", "yes", "true")


def kernel_mode(raw: str | None = None) -> str:
    """Resolve the `REPRO_SIM_KERNELS` knob to a dispatch mode:
    'off' | 'xla' | 'pallas' | 'pallas-interpret'.

    Idempotent: resolved modes pass through unchanged, so callers may
    hand either the raw knob value or an already-resolved mode. `xla`
    forces the fused XLA formulation even on TPU; `pallas-interpret`
    forces the Pallas kernels through the interpreter on any backend.
    """
    if raw is None:
        raw = os.environ.get("REPRO_SIM_KERNELS", "1")
    raw = raw.strip().lower()
    if raw in _OFF:
        return "off"
    if raw in ("xla", "pallas-interpret"):
        return raw
    on_tpu = jax.default_backend() == "tpu"
    if raw == "pallas":
        return "pallas" if on_tpu else "pallas-interpret"
    if raw in _AUTO:
        return "pallas" if on_tpu else "xla"
    raise ValueError(
        f"REPRO_SIM_KERNELS={raw!r}: expected one of "
        f"{_OFF + _AUTO + ('pallas', 'pallas-interpret', 'xla')}")


def etf_decide(avail, free, exec_t, now, slot_ok, pe_alive, *, mode):
    """Per-lane masked ETF search: avail/exec_t [R, P], free [P], now
    scalar, slot_ok [R] bool, pe_alive [P] bool or None (all alive).
    Returns (slot, pe, feasible) int32/int32/bool. Batches under vmap.
    """
    if mode == "xla":
        DISPATCH_COUNT["etf_xla"] += 1
        _, slot, pe, ok = ref.etf_ft_masked_reference(
            avail, free, exec_t, now, slot_ok, pe_alive)
    else:
        key = "etf_pallas" if mode == "pallas" else "etf_pallas_interpret"
        DISPATCH_COUNT[key] += 1
        alive = (jnp.ones(avail.shape[-1], bool) if pe_alive is None
                 else pe_alive)
        _, slot, pe, ok = kernel.etf_ft_search_masked(
            avail[None], free[None], exec_t[None], now[None],
            slot_ok[None], alive[None],
            interpret=(mode != "pallas"))
        slot, pe, ok = slot[0], pe[0], ok[0]
    return slot.astype(jnp.int32), pe.astype(jnp.int32), ok


def push_rows(pfin, cost, pcl, pv, pe_cluster, bases, n_clusters, *,
              mode):
    """Per-lane push-time availability rows: pfin/cost/pcl/pv [K, MP],
    pe_cluster [P], bases [K], n_clusters static. Returns [K, P].
    Batches under vmap."""
    if mode == "xla":
        DISPATCH_COUNT["push_xla"] += 1
        return ref.push_rows_reference(pfin, cost, pcl, pv, pe_cluster,
                                       bases, n_clusters)
    key = "push_pallas" if mode == "pallas" else "push_pallas_interpret"
    DISPATCH_COUNT[key] += 1
    out = kernel.push_rows(pfin[None], cost[None],
                           pcl[None], pv[None], pe_cluster, bases[None],
                           interpret=(mode != "pallas"))
    return out[0]


def interpret_batch_limit(r: int, p: int) -> int:
    """Largest batch the interpret-mode search kernel accepts before
    `etf_ft` falls back to the jnp reference, derived from the kernel's
    own block geometry (`kernel.MAX_INTERPRET_CELLS` over the [R, Pp]
    block) instead of a hard-coded batch count. Override the cell budget
    with `REPRO_ETF_FT_INTERPRET_CELLS`."""
    cells = kernel.MAX_INTERPRET_CELLS
    env = os.environ.get("REPRO_ETF_FT_INTERPRET_CELLS")
    if env is not None:
        cells = int(env)
    block = r * kernel._pad_lanes(p)
    return max(1, cells // block)


def etf_ft(avail, free, exec_t, now, *, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, R, P = avail.shape
    if interpret and B > interpret_batch_limit(R, P):
        DISPATCH_COUNT["etf_ft_ref_fallback"] += 1
        return ref.etf_ft_reference(avail, free, exec_t, now)
    return kernel.etf_ft_search(avail, free, exec_t, now,
                                interpret=interpret)

"""Pure-jnp oracles / fused XLA formulations for the decision kernels.

`etf_ft_reference` is the original unbatched-shape oracle the property
tests compare the Pallas kernel against. The two `*_masked` / `push_*`
functions below are the single fused, jit-friendly XLA formulations the
dispatch layer (`ops.py`) uses on non-TPU backends: rank-polymorphic over
leading batch axes so they trace identically inside `vmap`'d simulator
steps, and bit-exact against the simulator's inline jnp path (same
first-global-minimum argmin tie-break, same floating-point ops in the
same order).
"""
from __future__ import annotations

import jax.numpy as jnp

BIG = 3.4e38


def etf_ft_reference(avail, free, exec_t, now):
    """avail [B,R,P], free [B,P], exec_t [B,R,P], now [B] ->
    (ft_min [B], slot [B], pe [B])."""
    ft = jnp.maximum(jnp.maximum(avail, free[:, None, :]),
                     now[:, None, None]) + exec_t
    ft = jnp.where(jnp.isfinite(ft), ft, BIG)
    B, R, P = ft.shape
    flat = ft.reshape(B, -1)
    idx = jnp.argmin(flat, axis=1)
    return (jnp.take_along_axis(flat, idx[:, None], 1)[:, 0],
            idx // P, idx % P)


def etf_ft_masked_reference(avail, free, exec_t, now, slot_ok,
                            pe_alive=None):
    """Masked decision search, rank-polymorphic over leading batch axes.

    avail/exec_t [..., R, P], free [..., P], now [...] scalar per batch
    element, slot_ok [..., R] bool, pe_alive [..., P] bool or None (all
    alive). Returns (ft_min, slot, pe, feasible) with the simulator's
    tie-break: first global minimum of the flattened masked [R, P]
    matrix; slot 0 / pe 0 (feasible=False) when everything is masked.
    """
    ft = jnp.maximum(jnp.maximum(avail, free[..., None, :]),
                     now[..., None, None]) + exec_t
    mask = slot_ok[..., :, None]
    if pe_alive is not None:
        mask = mask & pe_alive[..., None, :]
    ft = jnp.where(mask & jnp.isfinite(ft), ft, BIG)
    R, P = ft.shape[-2], ft.shape[-1]
    flat = ft.reshape(ft.shape[:-2] + (R * P,))
    idx = jnp.argmin(flat, axis=-1)
    ft_min = jnp.take_along_axis(flat, idx[..., None], -1)[..., 0]
    return ft_min, idx // P, idx % P, ft_min < BIG


def push_rows_reference(pfin, cost, pcl, pv, pe_cluster, bases,
                        n_clusters):
    """Fused push-time availability rows, rank-polymorphic over leading
    batch axes.

    pfin/cost/pcl/pv [..., K, MP] (pred finish, NoC transfer cost, pred
    cluster id, validity), pe_cluster [P], bases [..., K], n_clusters
    static (unused — kept so the dispatch signature matches the Pallas
    kernel's geometry needs). Returns rows [..., K, P] ==
    max(max over valid preds of (pfin + cost * (pcl != cluster(p))),
        bases).

    Deliberately the same broadcast-max the simulator inlines (a
    per-source-cluster [.., K, C] decomposition benchmarked ~20% slower
    on CPU: the intermediates cost more than the [K, MP, P] tensor at
    these sizes) — identical op order keeps it bitwise equal to the
    inline path, and XLA fuses the whole thing into one reduction.
    """
    del n_clusters
    cross = pcl[..., :, :, None] != pe_cluster      # [..., K, MP, P]
    contrib = jnp.where(pv[..., None],
                        pfin[..., None] + cost[..., None] * cross,
                        jnp.float32(-jnp.inf))
    return jnp.maximum(contrib.max(axis=-2), bases[..., None])

"""Pure-jnp oracle for the ETF finish-time search."""
from __future__ import annotations

import jax.numpy as jnp


def etf_ft_reference(avail, free, exec_t, now):
    """avail [B,R,P], free [B,P], exec_t [B,R,P], now [B] ->
    (ft_min [B], slot [B], pe [B])."""
    ft = jnp.maximum(jnp.maximum(avail, free[:, None, :]),
                     now[:, None, None]) + exec_t
    ft = jnp.where(jnp.isfinite(ft), ft, 3.4e38)
    B, R, P = ft.shape
    flat = ft.reshape(B, -1)
    idx = jnp.argmin(flat, axis=1)
    return (jnp.take_along_axis(flat, idx[:, None], 1)[:, 0],
            idx // P, idx % P)

"""Pure-jnp oracle: the defining sequential recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rg_lru_reference(a, b):
    """a, b [B, S, C] -> h [B, S, C]; h_t = a_t h_{t-1} + b_t, h_{-1}=0."""
    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h
    xs = (a.transpose(1, 0, 2).astype(jnp.float32),
          b.transpose(1, 0, 2).astype(jnp.float32))
    h0 = jnp.zeros(a.shape[::2], jnp.float32)  # [B, C]
    _, hs = jax.lax.scan(step, h0, xs)
    return hs.transpose(1, 0, 2).astype(a.dtype)

"""RG-LRU linear-recurrence kernel (TPU Pallas).

h_t = a_t * h_{t-1} + b_t, per channel. The recurrence is inherently
sequential in t but fully parallel over (batch, channel); the kernel tiles
channels into lane-aligned VMEM blocks (block_c multiple of 128), carries
h in VMEM scratch across sequential chunk grid steps, and walks time with
a fori_loop of pure VPU ops — this layer is HBM-bandwidth-bound (state
never leaves VMEM; a/b stream through once), which is the TPU-native
adaptation of Griffin's custom scan.

Grid = (batch, channel_blocks, time_chunks), time innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rg_kernel(a_ref, b_ref, y_ref, h_ref, *, chunk):
    tb = pl.program_id(2)

    @pl.when(tb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)      # [Q, C]
    b = b_ref[0].astype(jnp.float32)      # [Q, C]

    def body(t, carry):
        h, ybuf = carry
        h = a[t] * h + b[t]
        ybuf = jax.lax.dynamic_update_index_in_dim(ybuf, h, t, 0)
        return h, ybuf

    h0 = h_ref[0]
    h, y = jax.lax.fori_loop(
        0, chunk, body, (h0, jnp.zeros((chunk, a.shape[1]), jnp.float32)))
    h_ref[0] = h
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_c", "interpret"))
def rg_lru_fwd(a, b, *, chunk=128, block_c=512, interpret=False):
    """a, b [B, S, C] -> h sequence [B, S, C] (fp32 math)."""
    B, S, C = a.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    block_c = min(block_c, C)
    while C % block_c:
        block_c //= 2
    grid = (B, C // block_c, S // chunk)
    return pl.pallas_call(
        functools.partial(_rg_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_c), lambda b_, c, t: (b_, t, c)),
            pl.BlockSpec((1, chunk, block_c), lambda b_, c, t: (b_, t, c)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_c),
                               lambda b_, c, t: (b_, t, c)),
        out_shape=jax.ShapeDtypeStruct((B, S, C), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_c), jnp.float32)],
        interpret=interpret,
    )(a, b)

"""Jit'd wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import jax

from repro.kernels.rg_lru import kernel


def rg_lru_scan(a, b, *, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return kernel.rg_lru_fwd(a, b, interpret=interpret)

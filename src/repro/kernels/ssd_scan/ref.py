"""Pure-jnp oracle for the SSD kernel: the sequential state recurrence
   h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T ;  y_t = C_t h_t
(the mathematically-defining form, O(S) scan — slow but unambiguous)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_reference(x, dt, A, Bh, Ch):
    """x [B,S,H,P], dt [B,S,H], A [H], Bh/Ch [B,S,H,N] ->
    (y [B,S,H,P], h_last [B,H,N,P])."""
    B, S, H, P = x.shape
    N = Bh.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp                     # [B,H,P],[B,H],[B,H,N]
        a = jnp.exp(dtt * A[None, :])             # [B,H]
        upd = jnp.einsum("bhn,bhp->bhnp", bt, xt * dtt[..., None])
        h = a[..., None, None] * h + upd
        y = jnp.einsum("bhn,bhnp->bhp", ct, h)
        return h, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bh.transpose(1, 0, 2, 3).astype(jnp.float32),
          Ch.transpose(1, 0, 2, 3).astype(jnp.float32))
    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    h_last, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h_last

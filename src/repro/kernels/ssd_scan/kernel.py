"""Mamba-2 SSD chunked-scan kernel (TPU Pallas).

Grid = (batch, head, n_chunks); the chunk axis is innermost/sequential and
the [N, P] inter-chunk state lives in VMEM scratch, so the recurrence never
round-trips HBM. Within a chunk the dual ("attention-like") form runs on
the MXU: (C B^T ⊙ L) (dt*X) with the cumulative-decay kernel L built from a
within-chunk cumsum — chunk 128 x state 128 x headdim 64 tiles are MXU
aligned and fit VMEM with room to spare.

Inputs are pre-grouped per head (B/C already expanded to heads, group
expansion happens in ops.py). All math fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, hlast_ref,
                h_ref, *, chunk):
    cb = pl.program_id(2)
    n_cb = pl.num_programs(2)

    @pl.when(cb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[0]                                        # scalar (per head)
    x = x_ref[0, 0, 0].astype(jnp.float32)              # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)            # [Q]
    Bm = b_ref[0, 0, 0].astype(jnp.float32)             # [Q, N]
    Cm = c_ref[0, 0, 0].astype(jnp.float32)             # [Q, N]

    dA = dt * A                                         # [Q] (A < 0)
    cum = jnp.cumsum(dA)                                # [Q]
    seg = cum[:, None] - cum[None, :]                   # [Q, Q]
    causal = (jax.lax.iota(jnp.int32, chunk)[:, None]
              >= jax.lax.iota(jnp.int32, chunk)[None, :])
    L = jnp.where(causal, jnp.exp(seg), 0.0)

    xdt = x * dt[:, None]                               # [Q, P]
    scores = (Cm @ Bm.T) * L                            # [Q, Q] (MXU)
    y = scores @ xdt                                    # intra-chunk

    h = h_ref[...]                                      # [N, P]
    in_decay = jnp.exp(cum)                             # [Q]
    y += (Cm * in_decay[:, None]) @ h                   # inter-chunk

    decay_to_end = jnp.exp(cum[-1] - cum)               # [Q]
    h_new = jnp.exp(cum[-1]) * h + (Bm * decay_to_end[:, None]).T @ xdt
    h_ref[...] = h_new

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(cb == n_cb - 1)
    def _finish():
        hlast_ref[0, 0] = h_new.astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_fwd(x, dt, A, Bh, Ch, *, chunk=128, interpret=False):
    """x [B,S,H,P], dt [B,S,H], A [H], Bh/Ch [B,S,H,N] (already per-head).
    Returns (y [B,S,H,P], h_last [B,H,N,P])."""
    B, S, H, P = x.shape
    N = Bh.shape[-1]
    assert S % chunk == 0
    nc = S // chunk

    xt = x.transpose(0, 2, 1, 3).reshape(B, H, nc, chunk, P)
    dtt = dt.transpose(0, 2, 1).reshape(B, H, nc, chunk)
    bt = Bh.transpose(0, 2, 1, 3).reshape(B, H, nc, chunk, N)
    ct = Ch.transpose(0, 2, 1, 3).reshape(B, H, nc, chunk, N)

    grid = (B, H, nc)
    y, hlast = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, chunk, N), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, N), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, chunk, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), xt, dtt, bt, ct)

    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    return y, hlast

"""Jit'd wrapper: expands B/C groups to heads and dispatches to the Pallas
kernel (TPU) / interpret mode (tests) / the model's chunked-jnp fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel


def ssd(xh, dth, A, Bg, Cg, *, chunk=128, interpret=None):
    """xh [B,S,H,P], dth [B,S,H], A [H], Bg/Cg [B,S,G,N] with H % G == 0.
    Returns (y [B,S,H,P], h_last [B,H,N,P])."""
    H = xh.shape[2]
    G = Bg.shape[2]
    rep = H // G
    Bh = jnp.repeat(Bg, rep, axis=2)
    Ch = jnp.repeat(Cg, rep, axis=2)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return kernel.ssd_fwd(xh, dth, A, Bh, Ch, chunk=chunk,
                          interpret=interpret)

"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mha_reference(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q [B,S,H,Dh], k/v [B,S,K,Dh] -> [B,S,H,Dh] (fp32 softmax)."""
    B, S, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(Dh)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(S)
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= pos[None, :] <= pos[:, None]
    if window:
        ok &= pos[None, :] > pos[:, None] - window
    s = jnp.where(ok[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, Dh).astype(q.dtype)

"""Flash attention forward kernel (TPU Pallas).

Online-softmax tiling (FlashAttention-2 style) adapted to the TPU memory
hierarchy:
  * grid = (batch*kv_heads, q_group, q_block, kv_block); the kv sweep is the
    innermost (sequential) grid dim, so K/V tiles stream HBM -> VMEM while
    the Q tile and the (acc, m, l) accumulators stay resident in VMEM
    scratch across the sweep;
  * block sizes default to 128x128: lane-aligned for the MXU (128x128
    systolic array) and small enough that q + k + v + acc + p tiles fit in
    ~16 MB VMEM even at d_head 256;
  * GQA: q heads are grouped over kv heads so a K/V tile is reused G times
    before moving on.

Supports causal masking, sliding window and logit softcap. Fully-masked
kv blocks are still visited (masked to -1e30) — on real TPUs a causal
grid-skip would halve the work; recorded as a perf note.

Validated against ref.mha_reference with interpret=True over shape/dtype
sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale, causal, window, softcap, block_q, block_k):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    n_kb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, d]
    k = k_ref[0].astype(jnp.float32)                     # [bk, d]
    v = v_ref[0].astype(jnp.float32)                     # [bk, dv]

    s = q @ k.T                                          # [bq, bk] (MXU)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = qb * block_q + jax.lax.iota(jnp.int32, block_q)
    k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + (p @ v)
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(kb == n_kb - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[:, 0], 1e-20)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"))
def flash_attention_fwd(q, k, v, *, causal=True, window=0, softcap=0.0,
                        block_q=128, block_k=128, interpret=False):
    """q [B,S,H,Dh], k/v [B,S,K,Dh] -> [B,S,H,Dh]. S % block sizes == 0."""
    B, S, H, Dh = q.shape
    K = k.shape[2]
    Dv = v.shape[3]
    G = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = 1.0 / np.sqrt(Dh)

    # [B,S,H,D] -> [B*K, G, S, D]; K/V -> [B*K, S, D]
    qh = q.reshape(B, S, K, G, Dh).transpose(0, 2, 3, 1, 4) \
          .reshape(B * K, G, S, Dh)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, S, Dh)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, S, Dv)

    grid = (B * K, G, S // block_q, S // block_k)
    out = pl.pallas_call(
        functools.partial(
            _fa_kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh),
                         lambda b, g, i, j: (b, g, i, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda b, g, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, Dv), lambda b, g, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, g, i, j: (b, g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, G, S, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dv), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)

    return out.reshape(B, K, G, S, Dv).transpose(0, 3, 1, 2, 4) \
              .reshape(B, S, H, Dv)

"""Jit'd public wrapper for the flash attention kernel.

On TPU targets the Pallas kernel; everywhere else (CPU dry-run/tests) it
falls back to the reference unless interpret mode is forced.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import kernel, ref


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if interpret and jax.default_backend() != "tpu":
        # CPU path: interpret-mode Pallas is O(grid) python -> use it only
        # for small shapes (tests); otherwise the jnp oracle.
        n_tiles = (q.shape[0] * k.shape[2]
                   * max(q.shape[1] // block_q, 1)
                   * max(q.shape[1] // block_k, 1))
        if n_tiles > 4096:
            return ref.mha_reference(q, k, v, causal=causal, window=window,
                                     softcap=softcap)
    return kernel.flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret)

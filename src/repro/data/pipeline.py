"""Data pipeline: synthetic + file-backed token streams with background
prefetch and deterministic resume.

`SyntheticLM` generates a learnable distribution (noisy affine next-token
process) so integration tests can assert the loss actually decreases.
`TokenFileDataset` memory-maps pre-tokenized uint16/int32 shards.
`Prefetcher` overlaps host batch assembly with device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLM:
    """next = (a*prev + c) % V with probability (1-noise), else uniform."""

    def __init__(self, vocab: int, batch: int, seq_len: int,
                 n_codebooks: int = 1, noise: float = 0.1,
                 a: int = 31, c: int = 7, seed: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq_len
        self.K = n_codebooks
        self.noise, self.a, self.c = noise, a, c
        self.seed = seed
        self.step = 0

    def set_step(self, step: int):
        self.step = step

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 100003 + self.step)
                                    % (2 ** 31 - 1))
        self.step += 1
        shape = ((self.batch, self.K, self.seq + 1) if self.K > 1
                 else (self.batch, self.seq + 1))
        toks = np.empty(shape, np.int32)
        first = rng.randint(0, self.vocab, shape[:-1])
        toks[..., 0] = first
        for t in range(1, self.seq + 1):
            nxt = (self.a * toks[..., t - 1] + self.c) % self.vocab
            flip = rng.rand(*shape[:-1]) < self.noise
            rand = rng.randint(0, self.vocab, shape[:-1])
            toks[..., t] = np.where(flip, rand, nxt)
        return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}


class TokenFileDataset:
    """Memory-mapped token shards: files of raw int32 tokens. Batches are
    sequential windows with deterministic shuffled shard order; `set_step`
    makes resume exact."""

    def __init__(self, paths, batch: int, seq_len: int, seed: int = 0):
        self.mms = [np.memmap(p, dtype=np.int32, mode="r") for p in paths]
        self.sizes = [len(m) for m in self.mms]
        self.batch, self.seq = batch, seq_len
        self.seed = seed
        self.step = 0
        self.total_windows = sum(s // (seq_len + 1) for s in self.sizes)

    def set_step(self, step: int):
        self.step = step

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(self.seed)
        order = rng.permutation(self.total_windows)
        toks = np.empty((self.batch, self.seq + 1), np.int32)
        for i in range(self.batch):
            w = order[(self.step * self.batch + i) % self.total_windows]
            # locate window w across shards
            for m, size in zip(self.mms, self.sizes):
                nw = size // (self.seq + 1)
                if w < nw:
                    s0 = w * (self.seq + 1)
                    toks[i] = m[s0:s0 + self.seq + 1]
                    break
                w -= nw
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Background-thread prefetch with bounded queue."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.it = it
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        try:
            for item in self.it:
                if self._stop.is_set():
                    return
                self.q.put(item)
        except BaseException as e:
            self.q.put(e)
        self.q.put(StopIteration())

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if isinstance(item, StopIteration):
            raise item
        if isinstance(item, BaseException):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
